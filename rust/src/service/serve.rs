//! The always-on selection daemon behind `repro serve`.
//!
//! A [`Server`] owns one TCP listener and three long-lived threads:
//!
//! * the **accept loop**, spawning one handler thread per connection;
//! * the **batcher**, which coalesces in-flight select requests from
//!   all connections into single [`select_with_predictions`] calls
//!   (one [`crate::etrm::Etrm::select_batch`]-equivalent pass instead
//!   of per-request model walks) — it snapshots the serving model
//!   *once per batch*, so a hot reload changes answers only at a
//!   request boundary, never inside one;
//! * the optional **reload poller**, probing the artifact's
//!   fingerprint ([`ModelHandle::reload_if_changed`]) on a timer. A
//!   stale or corrupt replacement artifact is rejected and the loaded
//!   model keeps serving — swapping a bad file under a live daemon
//!   costs nothing but a log line.
//!
//! Failure containment: a framing error (bad checksum, truncated
//! frame, mid-request disconnect) desyncs only that connection, which
//! is dropped cleanly; a well-framed but malformed request gets a
//! [`proto::FRAME_ERR`] reply and the connection keeps serving. The
//! daemon itself never panics on client bytes.
//!
//! Shutdown ([`proto::FRAME_SHUTDOWN`]) is drain-then-exit: new
//! selects are refused, in-flight ones finish and are answered, then
//! every connection is closed and [`Server::join`] returns the
//! lifetime counters. No clocks run here — pacing is sleep-tick based,
//! so the daemon stays out of the audit's `Instant::now()` rule.

use std::collections::BTreeMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::engine::wire;
use crate::features::TaskFeatures;
use crate::partition::Strategy;
use crate::util::error::{Context, Result};

use super::app::{select_with_predictions, LoadedModel, ModelHandle, Reload};
use super::proto;

/// Daemon configuration (the `repro serve` flags, typed).
pub struct ServeConfig {
    /// `host:port` to bind; port 0 picks a free port (the chosen
    /// address is [`Server::local_addr`]).
    pub listen: String,
    /// Selection parallelism (0 = `GPS_THREADS` / available cores).
    pub threads: usize,
    /// Hot-reload probe period; 0 disables the poller (reloads then
    /// happen only on explicit [`proto::FRAME_RELOAD`] requests).
    pub reload_poll_ms: u64,
    /// Max select requests coalesced into one batched model pass.
    pub max_coalesce: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            listen: "127.0.0.1:0".to_string(),
            threads: 0,
            reload_poll_ms: 200,
            max_coalesce: 64,
        }
    }
}

/// Lifetime counters reported by [`Server::join`].
pub struct ServeSummary {
    /// Select requests answered.
    pub requests: u64,
    /// Tasks selected across all requests.
    pub tasks: u64,
    /// Batched model passes (≤ requests thanks to coalescing).
    pub batches: u64,
}

struct Shared {
    handle: ModelHandle,
    threads: usize,
    max_coalesce: usize,
    shutdown: AtomicBool,
    in_flight: AtomicU64,
    requests: AtomicU64,
    tasks: AtomicU64,
    batches: AtomicU64,
    /// Clone of every live connection, keyed by connection id, so the
    /// shutdown path can unblock idle readers.
    conns: Mutex<BTreeMap<u64, TcpStream>>,
}

/// One coalescable unit of work: a decoded request plus the channel
/// its reply travels back on.
struct Job {
    tasks: Vec<TaskFeatures>,
    want_bits: bool,
    reply: mpsc::Sender<Batched>,
}

/// A job's share of a batched selection, pinned to the model
/// generation that computed it.
struct Batched {
    model: Arc<LoadedModel>,
    picks: Vec<Strategy>,
    preds: Option<Vec<Vec<(Strategy, f64)>>>,
}

/// Decrements the in-flight counter when the request's reply has been
/// written (or abandoned) — the drain barrier shutdown waits on.
struct InFlightGuard<'a> {
    shared: &'a Shared,
}

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        self.shared.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A long-running selection daemon bound to one artifact path.
pub struct Server {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept: thread::JoinHandle<()>,
    batcher: thread::JoinHandle<()>,
    poller: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind, spawn the worker threads and start serving.
    pub fn start(cfg: ServeConfig, handle: ModelHandle) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("bind selection daemon on {}", cfg.listen))?;
        let local_addr = listener.local_addr().context("resolve daemon listen address")?;
        listener.set_nonblocking(true).context("set listener non-blocking")?;
        let shared = Arc::new(Shared {
            handle,
            threads: cfg.threads,
            max_coalesce: cfg.max_coalesce.max(1),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            conns: Mutex::new(BTreeMap::new()),
        });
        let (jobs_tx, jobs_rx) = mpsc::channel();
        let accept = {
            let shared = Arc::clone(&shared);
            thread::spawn(move || run_accept(&shared, &listener, &jobs_tx))
        };
        let batcher = {
            let shared = Arc::clone(&shared);
            thread::spawn(move || run_batcher(&shared, &jobs_rx))
        };
        let poller = if cfg.reload_poll_ms > 0 {
            let shared = Arc::clone(&shared);
            let poll_ms = cfg.reload_poll_ms;
            Some(thread::spawn(move || run_poller(&shared, poll_ms)))
        } else {
            None
        };
        Ok(Server { shared, local_addr, accept, batcher, poller })
    }

    /// The bound address (resolves `--listen 127.0.0.1:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Snapshot of the currently serving model.
    pub fn model(&self) -> Arc<LoadedModel> {
        self.shared.handle.current()
    }

    /// Block until a client-initiated shutdown has drained the daemon,
    /// then return the lifetime counters.
    pub fn join(self) -> Result<ServeSummary> {
        self.accept.join().map_err(|_| crate::err!("daemon accept thread panicked"))?;
        self.batcher.join().map_err(|_| crate::err!("daemon batcher thread panicked"))?;
        if let Some(poller) = self.poller {
            poller.join().map_err(|_| crate::err!("daemon reload poller panicked"))?;
        }
        Ok(ServeSummary {
            requests: self.shared.requests.load(Ordering::SeqCst),
            tasks: self.shared.tasks.load(Ordering::SeqCst),
            batches: self.shared.batches.load(Ordering::SeqCst),
        })
    }
}

fn lock_conns(shared: &Shared) -> std::sync::MutexGuard<'_, BTreeMap<u64, TcpStream>> {
    shared.conns.lock().unwrap_or_else(|e| e.into_inner())
}

fn run_accept(shared: &Arc<Shared>, listener: &TcpListener, jobs: &mpsc::Sender<Job>) {
    let mut next_id = 0u64;
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return; // drops the master job sender: the batcher drains and exits
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if stream.set_nonblocking(false).is_err() || stream.set_nodelay(true).is_err() {
                    continue;
                }
                let conn_id = next_id;
                next_id += 1;
                if let Ok(clone) = stream.try_clone() {
                    lock_conns(shared).insert(conn_id, clone);
                }
                let shared = Arc::clone(shared);
                let jobs = jobs.clone();
                thread::spawn(move || run_conn(&shared, &jobs, stream, conn_id));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn run_conn(shared: &Arc<Shared>, jobs: &mpsc::Sender<Job>, mut stream: TcpStream, conn_id: u64) {
    let mut scratch = proto::RequestScratch::new();
    loop {
        // a framing failure (bad checksum, truncated frame, disconnect)
        // leaves the byte stream unparseable — drop the connection
        // cleanly; the daemon itself keeps serving everyone else
        let (kind, payload) = match wire::read_frame(&mut stream) {
            Ok(frame) => frame,
            Err(_) => break,
        };
        match handle_frame(shared, jobs, &mut stream, &mut scratch, kind, &payload) {
            Ok(true) => {}
            Ok(false) | Err(_) => break, // shutdown, or the peer is gone
        }
    }
    lock_conns(shared).remove(&conn_id);
}

/// Serve one well-framed request. `Ok(true)` keeps the connection,
/// `Ok(false)` ends it deliberately, `Err` means the reply could not
/// be written (the peer disconnected mid-request).
fn handle_frame(
    shared: &Arc<Shared>,
    jobs: &mpsc::Sender<Job>,
    stream: &mut TcpStream,
    scratch: &mut proto::RequestScratch,
    kind: u8,
    payload: &[u8],
) -> Result<bool> {
    match kind {
        proto::FRAME_PING => {
            wire::write_frame(stream, proto::FRAME_PONG, &[])?;
            Ok(true)
        }
        proto::FRAME_SELECT => {
            let want_bits = match proto::decode_select_request(payload, scratch) {
                Ok(want) => want,
                Err(e) => {
                    // well-framed but malformed: error reply, connection survives
                    let err = proto::encode_err(&e.to_string());
                    wire::write_frame(stream, proto::FRAME_ERR, &err)?;
                    return Ok(true);
                }
            };
            if shared.shutdown.load(Ordering::SeqCst) {
                let err = proto::encode_err("daemon is shutting down");
                wire::write_frame(stream, proto::FRAME_ERR, &err)?;
                return Ok(true);
            }
            shared.in_flight.fetch_add(1, Ordering::SeqCst);
            let guard = InFlightGuard { shared };
            let (reply_tx, reply_rx) = mpsc::channel();
            let job = Job { tasks: scratch.tasks.clone(), want_bits, reply: reply_tx };
            let batched = match jobs.send(job) {
                Ok(()) => reply_rx.recv().ok(),
                Err(_) => None,
            };
            let Some(batched) = batched else {
                drop(guard);
                let err = proto::encode_err("daemon is shutting down");
                wire::write_frame(stream, proto::FRAME_ERR, &err)?;
                return Ok(true);
            };
            shared.requests.fetch_add(1, Ordering::SeqCst);
            let reply = proto::encode_select_reply(
                batched.model.fingerprint,
                batched.model.etrm.backend.name(),
                batched.model.etrm.label.name(),
                &batched.picks,
                batched.preds.as_deref(),
            );
            let written = wire::write_frame(stream, proto::FRAME_SELECT_OK, &reply);
            drop(guard); // reply done (or abandoned): release the drain barrier
            written?;
            Ok(true)
        }
        proto::FRAME_RELOAD => {
            let (status, message) = match shared.handle.reload_if_changed() {
                Reload::Unchanged => (proto::ReloadStatus::Unchanged, String::new()),
                Reload::Reloaded { from, to } => {
                    (proto::ReloadStatus::Reloaded, format!("{from:016x} -> {to:016x}"))
                }
                Reload::Rejected { error } => (proto::ReloadStatus::Rejected, error),
            };
            let fingerprint = shared.handle.current().fingerprint;
            let reply = proto::encode_reload_reply(status, fingerprint, &message);
            wire::write_frame(stream, proto::FRAME_RELOAD_OK, &reply)?;
            Ok(true)
        }
        proto::FRAME_SHUTDOWN => {
            shared.shutdown.store(true, Ordering::SeqCst);
            // drain: every accepted select is either answered already
            // or counted in in_flight — wait for the barrier to clear
            while shared.in_flight.load(Ordering::SeqCst) > 0 {
                thread::sleep(Duration::from_millis(5));
            }
            let total = shared.requests.load(Ordering::SeqCst);
            let reply = proto::encode_shutdown_reply(total);
            wire::write_frame(stream, proto::FRAME_SHUTDOWN_OK, &reply)?;
            // unblock every idle reader so handler threads exit promptly
            for conn in lock_conns(shared).values() {
                let _ = conn.shutdown(Shutdown::Both);
            }
            Ok(false)
        }
        other => {
            let err = proto::encode_err(&format!("unknown service frame kind {other:#04x}"));
            wire::write_frame(stream, proto::FRAME_ERR, &err)?;
            Ok(true)
        }
    }
}

/// The coalescing batcher: pull one job, greedily drain whatever else
/// is already queued (up to `max_coalesce`), run ONE batched selection
/// over the concatenated tasks against ONE model snapshot, then split
/// the results back per job. Exits when every job sender is gone.
fn run_batcher(shared: &Shared, jobs: &mpsc::Receiver<Job>) {
    loop {
        let first = match jobs.recv() {
            Ok(job) => job,
            Err(_) => return,
        };
        let mut batch = vec![first];
        while batch.len() < shared.max_coalesce {
            match jobs.try_recv() {
                Ok(job) => batch.push(job),
                Err(_) => break,
            }
        }
        // one snapshot per batch: a concurrent hot reload lands at a
        // request boundary, never inside a request
        let model = shared.handle.current();
        let mut all: Vec<TaskFeatures> = Vec::new();
        for job in &batch {
            all.extend(job.tasks.iter().cloned());
        }
        let want_bits = batch.iter().any(|job| job.want_bits);
        let sel = select_with_predictions(&model.etrm, &all, shared.threads, want_bits);
        shared.batches.fetch_add(1, Ordering::SeqCst);
        shared.tasks.fetch_add(all.len() as u64, Ordering::SeqCst);
        let mut offset = 0usize;
        for job in batch {
            let n = job.tasks.len();
            let picks = sel.picks[offset..offset + n].to_vec();
            let preds = if job.want_bits {
                sel.predictions.as_ref().map(|tables| tables[offset..offset + n].to_vec())
            } else {
                None
            };
            offset += n;
            // a send failure means the requester disconnected mid-wait;
            // its guard already released the drain barrier
            let _ = job.reply.send(Batched { model: Arc::clone(&model), picks, preds });
        }
    }
}

/// The hot-reload poller: probe the artifact fingerprint every
/// `poll_ms`, sleeping in short ticks so shutdown stays prompt.
/// Repeated rejections of the same bad artifact log once, not per tick.
fn run_poller(shared: &Shared, poll_ms: u64) {
    let mut last_error = String::new();
    loop {
        let mut waited = 0u64;
        while waited < poll_ms {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let step = (poll_ms - waited).min(50);
            thread::sleep(Duration::from_millis(step));
            waited += step;
        }
        match shared.handle.reload_if_changed() {
            Reload::Unchanged => {}
            Reload::Reloaded { from, to } => {
                last_error.clear();
                eprintln!("serve: model hot-reloaded ({from:016x} -> {to:016x})");
            }
            Reload::Rejected { error } => {
                if error != last_error {
                    eprintln!(
                        "serve: rejected artifact swap, still serving the loaded model: {error}"
                    );
                    last_error = error;
                }
            }
        }
    }
}
