//! PowerLyra Hybrid partitioning (PSID 5, §3.3.3-i).
//!
//! Differentiated by in-degree: a *low-degree* vertex `v`
//! (`in_degree(v) ≤ threshold`) has **all of its in-edges** assigned to
//! the single worker `hash(v)` — co-locating the gather neighbourhood —
//! while a *high-degree* vertex's in-edges are spread by hashing each
//! edge's **source**, avoiding the load concentration a power-law hub
//! would otherwise cause.

use crate::graph::Graph;
use crate::util::rng::hash_u64;

use super::{map_edges, worker_of_hash, Partitioning};

/// PowerLyra's default degree threshold.
pub const DEFAULT_THRESHOLD: usize = 100;

/// PSID 5 — hybrid-cut with the given in-degree threshold (sequential
/// reference path).
pub fn partition(g: &Graph, num_workers: usize, threshold: usize) -> Partitioning {
    partition_threads(g, num_workers, threshold, 1)
}

/// PSID 5 with up to `threads` pool threads. The degree "precompute"
/// is the graph's own CSR (`in_degree` is an O(1) lookup), so the
/// whole assignment is a pure per-edge function and the chunked
/// parallel map is byte-identical.
pub fn partition_threads(
    g: &Graph,
    num_workers: usize,
    threshold: usize,
    threads: usize,
) -> Partitioning {
    let assign = map_edges(g, threads, |(u, v)| {
        if g.in_degree(v) <= threshold {
            worker_of_hash(hash_u64(v as u64), num_workers)
        } else {
            worker_of_hash(hash_u64(u as u64), num_workers)
        }
    });
    Partitioning::from_edge_assignment_threads(g, num_workers, assign, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn low_degree_in_edges_colocate() {
        // v=5 has in-degree 3 (≤ threshold) → all in-edges on one worker
        let g = Graph::from_edges("h", 10, vec![(0, 5), (1, 5), (2, 5), (0, 1)], true);
        let p = partition(&g, 4, 100);
        let ws: Vec<u16> = g
            .edges()
            .iter()
            .zip(&p.edge_worker)
            .filter(|(&(_, v), _)| v == 5)
            .map(|(_, &w)| w)
            .collect();
        assert_eq!(ws.len(), 3);
        assert!(ws.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn high_degree_in_edges_spread_by_source() {
        // hub vertex 0 with in-degree 50 > threshold 10
        let edges: Vec<(u32, u32)> = (1..=50).map(|u| (u as u32, 0)).collect();
        let g = Graph::from_edges("hub", 51, edges, true);
        let p = partition(&g, 8, 10);
        let distinct: std::collections::BTreeSet<u16> = p.edge_worker.iter().copied().collect();
        assert!(distinct.len() > 1, "hub edges must spread, got {distinct:?}");
        // and the assignment matches 1DSrc for those edges
        let by_src = crate::partition::oned::partition_src(&g, 8);
        assert_eq!(p.edge_worker, by_src.edge_worker);
    }

    #[test]
    fn threshold_zero_equals_pure_src_hash_on_nonisolated() {
        let mut rng = crate::util::rng::Rng::new(60);
        let g = crate::graph::gen::erdos::generate("t", 100, 500, true, &mut rng);
        let p0 = partition(&g, 4, 0);
        let psrc = crate::partition::oned::partition_src(&g, 4);
        // every destination has in-degree ≥ 1 > 0 → all high-degree
        assert_eq!(p0.edge_worker, psrc.edge_worker);
    }
}
