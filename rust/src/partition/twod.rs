//! 2-D edge partitioning (PSID 4, §3.3.1-iv — GraphX `EdgePartition2D`).
//!
//! Workers are arranged in an `r × c` grid (square when `|W|` is a
//! perfect square); an edge `(u, v)` goes to the tile at
//! `(hash(u) mod r, hash(v) mod c)`. Every vertex's replicas are then
//! confined to one grid row plus one grid column, bounding the
//! replication factor by `r + c` (= `2√|W|` for square grids — the
//! guarantee the paper quotes from GraphBuilder [15]).

use crate::graph::Graph;
use crate::util::rng::hash_u64;

use super::{map_edges, Partitioning};

/// Choose the most-square factorisation `r × c = w` with `r ≤ c`.
pub fn grid_shape(w: usize) -> (usize, usize) {
    let mut best = (1, w);
    let mut r = 1;
    while r * r <= w {
        if w % r == 0 {
            best = (r, w / r);
        }
        r += 1;
    }
    best
}

/// PSID 4 — two independent 1-D hashes onto a worker grid (sequential
/// reference path).
pub fn partition(g: &Graph, num_workers: usize) -> Partitioning {
    partition_threads(g, num_workers, 1)
}

/// PSID 4 with up to `threads` pool threads — the tile hash is a pure
/// per-edge function, so the chunked parallel map is byte-identical.
pub fn partition_threads(g: &Graph, num_workers: usize, threads: usize) -> Partitioning {
    let (rows, cols) = grid_shape(num_workers);
    let assign = map_edges(g, threads, |(u, v)| {
        let r = (hash_u64(u as u64) % rows as u64) as usize;
        let c = (hash_u64(v as u64 ^ 0x9e3779b9) % cols as u64) as usize;
        (r * cols + c) as u16
    });
    Partitioning::from_edge_assignment_threads(g, num_workers, assign, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::metrics::PartitionMetrics;

    #[test]
    fn grid_shapes() {
        assert_eq!(grid_shape(64), (8, 8));
        assert_eq!(grid_shape(16), (4, 4));
        assert_eq!(grid_shape(12), (3, 4));
        assert_eq!(grid_shape(7), (1, 7));
        assert_eq!(grid_shape(1), (1, 1));
    }

    #[test]
    fn replication_bounded_by_row_plus_col() {
        // On a square grid of w workers each vertex can appear in at most
        // 2√w tiles (its row as a source + its column as a destination).
        let mut rng = crate::util::rng::Rng::new(50);
        let g = crate::graph::gen::chung_lu::generate("t", 400, 6000, 2.1, true, &mut rng);
        let p = partition(&g, 16);
        let bound = 2 * 4; // 2√16
        for v in g.vertices() {
            assert!(
                p.replicas[v as usize].len() <= bound,
                "vertex {v} has {} replicas > bound {bound}",
                p.replicas[v as usize].len()
            );
        }
    }

    #[test]
    fn lower_replication_than_random_on_skewed_graph() {
        let mut rng = crate::util::rng::Rng::new(51);
        let g = crate::graph::gen::chung_lu::generate("t", 1000, 15_000, 2.05, true, &mut rng);
        let p2d = PartitionMetrics::of(&g, &partition(&g, 64));
        let prand = PartitionMetrics::of(&g, &crate::partition::random::partition_random(&g, 64));
        assert!(
            p2d.replication_factor < prand.replication_factor,
            "2d {} < random {}",
            p2d.replication_factor,
            prand.replication_factor
        );
    }
}
