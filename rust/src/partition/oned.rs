//! 1-D edge partitioning (PSID 0/1, §3.3.1-i and §3.3.4).
//!
//! `1DSrc` hashes the edge's **source** vertex id, so all out-edges of a
//! vertex land on one worker (GraphX's `EdgePartition1D`). `1DDst` is
//! the paper's custom mirror: hash the **destination**, co-locating all
//! in-edges — advantageous for gather-heavy pull algorithms like
//! PageRank on graphs with skewed in-degree.

use crate::graph::Graph;
use crate::util::rng::hash_u64;

use super::{map_edges, worker_of_hash, Partitioning};

/// PSID 0 — hash of the source vertex (sequential reference path).
pub fn partition_src(g: &Graph, num_workers: usize) -> Partitioning {
    partition_src_threads(g, num_workers, 1)
}

/// PSID 0 with up to `threads` pool threads — the hash is a pure
/// per-edge function, so the chunked parallel map is byte-identical.
pub fn partition_src_threads(g: &Graph, num_workers: usize, threads: usize) -> Partitioning {
    let assign = map_edges(g, threads, |(u, _)| worker_of_hash(hash_u64(u as u64), num_workers));
    Partitioning::from_edge_assignment_threads(g, num_workers, assign, threads)
}

/// PSID 1 — hash of the destination vertex (sequential reference path).
pub fn partition_dst(g: &Graph, num_workers: usize) -> Partitioning {
    partition_dst_threads(g, num_workers, 1)
}

/// PSID 1 with up to `threads` pool threads.
pub fn partition_dst_threads(g: &Graph, num_workers: usize, threads: usize) -> Partitioning {
    let assign = map_edges(g, threads, |(_, v)| worker_of_hash(hash_u64(v as u64), num_workers));
    Partitioning::from_edge_assignment_threads(g, num_workers, assign, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn src_colocates_out_edges() {
        let g = Graph::from_edges("s", 10, vec![(3, 1), (3, 5), (3, 9), (4, 2)], true);
        let p = partition_src(&g, 4);
        let ws: Vec<u16> = g
            .edges()
            .iter()
            .zip(&p.edge_worker)
            .filter(|(&(u, _), _)| u == 3)
            .map(|(_, &w)| w)
            .collect();
        assert!(ws.windows(2).all(|p| p[0] == p[1]), "same worker for all out-edges of 3");
    }

    #[test]
    fn dst_colocates_in_edges() {
        let g = Graph::from_edges("d", 10, vec![(1, 7), (2, 7), (9, 7), (4, 2)], true);
        let p = partition_dst(&g, 4);
        let ws: Vec<u16> = g
            .edges()
            .iter()
            .zip(&p.edge_worker)
            .filter(|(&(_, v), _)| v == 7)
            .map(|(_, &w)| w)
            .collect();
        assert!(ws.windows(2).all(|p| p[0] == p[1]));
    }

    #[test]
    fn src_and_dst_differ_on_asymmetric_graph() {
        let mut rng = crate::util::rng::Rng::new(40);
        let g = crate::graph::gen::chung_lu::generate("a", 300, 1500, 2.1, true, &mut rng);
        let a = partition_src(&g, 8).edge_worker;
        let b = partition_dst(&g, 8).edge_worker;
        assert_ne!(a, b);
    }
}
