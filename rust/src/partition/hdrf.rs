//! HDRF — High-Degree Replicated First (PSID 7-10, §3.3.2-iii,
//! Petroni et al. [38]).
//!
//! Streaming vertex-cut that preferentially replicates high-degree
//! vertices (their replicas are cheap relative to their edge count).
//! For each incoming edge `(u, v)` every worker is scored
//!
//! ```text
//! Score(u, v, w) = C_REP(u, v, w) + λ · C_BAL(w)          (paper Eq. 1)
//! C_REP = g(u, w) + g(v, w),
//! g(x, w) = [x ∈ replicas(w)] · (1 + (1 − δ'(x)))
//! δ'(u) = δ(u) / (δ(u) + δ(v))        (normalised partial degree)
//! C_BAL(w) = (maxload − load(w)) / (ε + maxload − minload)
//! ```
//!
//! and the edge goes to the argmax. The lower the partial degree of an
//! endpoint already present on `w`, the *higher* the reward — keeping
//! low-degree vertices intact and replicating hubs first. The paper
//! sweeps λ ∈ {10, 20, 50, 100} as PSIDs 7-10.

use crate::graph::Graph;

use super::oblivious::ReplicaSets;
use super::Partitioning;

const EPS: f64 = 1.0;

/// HDRF with balance weight `lambda` (sequential reference path).
///
/// The per-edge scoring scan is the partitioner's hot loop; for the
/// common `|W| ≤ 64` case each endpoint's replica set is a single
/// `u64` word, hoisted into registers so `C_REP` is two bit tests per
/// worker instead of two bounds-checked bitset lookups.
pub fn partition(g: &Graph, num_workers: usize, lambda: f64) -> Partitioning {
    partition_threads(g, num_workers, lambda, 1)
}

/// HDRF with up to `threads` pool threads. The streaming scoring loop
/// is order-dependent (scores read the loads and replica sets left by
/// every earlier edge) and stays sequential byte-for-byte; only the
/// replica/master derivation over the finished assignment fans over
/// the pool (per-chunk counts and bitsets, order-independent merge).
pub fn partition_threads(
    g: &Graph,
    num_workers: usize,
    lambda: f64,
    threads: usize,
) -> Partitioning {
    let n = g.num_vertices();
    let mut replicas = ReplicaSets::new(n, num_workers);
    let mut load = vec![0usize; num_workers];
    let mut partial_deg = vec![0u32; n];
    let mut assign = Vec::with_capacity(g.num_edges());
    let mut maxload = 0usize;
    let mut minload = 0usize;
    let mut cnt_min = num_workers; // workers at the current min level
    let reward_u = |norm_u: f64| 2.0 - norm_u;
    for &(u, v) in g.edges() {
        let du = partial_deg[u as usize] as f64 + 1.0;
        let dv = partial_deg[v as usize] as f64 + 1.0;
        let (norm_u, norm_v) = (du / (du + dv), dv / (du + dv));
        let (ru, rv) = (reward_u(norm_u), reward_u(norm_v));
        let inv_denom = lambda / (EPS + (maxload - minload) as f64);
        let mut best_w = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        if num_workers <= 64 {
            // fast path: replica membership as register bitmasks
            let wu = replicas.word0(u);
            let wv = replicas.word0(v);
            for w in 0..num_workers {
                let mut score = (maxload - load[w]) as f64 * inv_denom;
                if wu >> w & 1 == 1 {
                    score += ru;
                }
                if wv >> w & 1 == 1 {
                    score += rv;
                }
                if score > best_score {
                    best_score = score;
                    best_w = w;
                }
            }
        } else {
            for w in 0..num_workers {
                let mut score = (maxload - load[w]) as f64 * inv_denom;
                if replicas.contains(u, w) {
                    score += ru;
                }
                if replicas.contains(v, w) {
                    score += rv;
                }
                if score > best_score {
                    best_score = score;
                    best_w = w;
                }
            }
        }
        replicas.insert(u, best_w);
        replicas.insert(v, best_w);
        partial_deg[u as usize] += 1;
        partial_deg[v as usize] += 1;
        // incremental min/max-load maintenance: loads only grow by one,
        // so the min level advances exactly when its population empties
        // (amortised O(1) instead of an O(|W|) rescan per edge)
        if load[best_w] == minload {
            cnt_min -= 1;
        }
        load[best_w] += 1;
        maxload = maxload.max(load[best_w]);
        if cnt_min == 0 {
            minload += 1;
            cnt_min = load.iter().filter(|&&l| l == minload).count();
            debug_assert!(cnt_min > 0);
        }
        assign.push(best_w as u16);
    }
    Partitioning::from_edge_assignment_threads(g, num_workers, assign, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::metrics::PartitionMetrics;

    fn powerlaw(seed: u64) -> Graph {
        let mut rng = crate::util::rng::Rng::new(seed);
        crate::graph::gen::chung_lu::generate("t", 800, 8000, 2.1, true, &mut rng)
    }

    #[test]
    fn balances_load_tightly() {
        let g = powerlaw(80);
        let p = partition(&g, 16, 100.0);
        let m = PartitionMetrics::of(&g, &p);
        // λ=100 makes balance dominate: near-perfect edge balance
        assert!(m.edge_balance < 1.05, "imbalance {}", m.edge_balance);
        assert_eq!(m.workers_used, 16);
    }

    #[test]
    fn lower_replication_than_random() {
        let g = powerlaw(81);
        let mh = PartitionMetrics::of(&g, &partition(&g, 16, 10.0));
        let mr =
            PartitionMetrics::of(&g, &crate::partition::random::partition_random(&g, 16));
        assert!(mh.replication_factor < mr.replication_factor);
    }

    #[test]
    fn lambda_trades_replication_for_balance() {
        let g = powerlaw(82);
        let lo = PartitionMetrics::of(&g, &partition(&g, 16, 10.0));
        let hi = PartitionMetrics::of(&g, &partition(&g, 16, 100.0));
        assert!(
            hi.edge_balance <= lo.edge_balance + 1e-9,
            "higher λ balances better: {} vs {}",
            hi.edge_balance,
            lo.edge_balance
        );
        assert!(
            hi.replication_factor >= lo.replication_factor - 1e-9,
            "higher λ replicates more: {} vs {}",
            hi.replication_factor,
            lo.replication_factor
        );
    }

    #[test]
    fn replicates_hubs_first() {
        // star + one chain: the hub (0) should acquire replicas on more
        // workers than a typical leaf.
        let mut edges: Vec<(u32, u32)> = (1..=40).map(|i| (0u32, i)).collect();
        edges.extend((41..45).map(|i| (i, i + 1)));
        let g = Graph::from_edges("hub", 46, edges, true);
        let p = partition(&g, 8, 10.0);
        let hub_replicas = p.replicas[0].len();
        let leaf_replicas = p.replicas[1].len();
        assert!(hub_replicas > leaf_replicas, "hub {hub_replicas} vs leaf {leaf_replicas}");
    }
}
