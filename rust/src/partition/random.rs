//! Random 2-D hash partitioning (PSID 2/3, §3.3.1-ii/iii).
//!
//! `Random` feeds the ordered pair `(u, v)` through the Cantor pairing
//! function (the paper's ref [26]) and hashes the result — reversed
//! edges may land on different workers. `CanonicalRandom` sorts the pair
//! first, so `(u, v)` and `(v, u)` always co-locate (this is also what
//! PowerGraph calls `Random`, §3.3.2-i).

use crate::graph::Graph;
use crate::util::rng::{cantor_pair, fnv1a64};

use super::{map_edges, worker_of_hash, Partitioning};

fn pair_hash(a: u64, b: u64) -> u64 {
    // Cantor-pair to one dimension, then mix through FNV so the worker
    // id is uniform even though π is locally monotone.
    let p = cantor_pair(a, b);
    fnv1a64(&p.to_le_bytes())
}

/// PSID 2 — order-sensitive pair hash (sequential reference path).
pub fn partition_random(g: &Graph, num_workers: usize) -> Partitioning {
    partition_random_threads(g, num_workers, 1)
}

/// PSID 2 with up to `threads` pool threads — pure per-edge hash, so
/// the chunked parallel map is byte-identical.
pub fn partition_random_threads(g: &Graph, num_workers: usize, threads: usize) -> Partitioning {
    let assign =
        map_edges(g, threads, |(u, v)| worker_of_hash(pair_hash(u as u64, v as u64), num_workers));
    Partitioning::from_edge_assignment_threads(g, num_workers, assign, threads)
}

/// PSID 3 — order-insensitive (canonical) pair hash (sequential
/// reference path).
pub fn partition_canonical(g: &Graph, num_workers: usize) -> Partitioning {
    partition_canonical_threads(g, num_workers, 1)
}

/// PSID 3 with up to `threads` pool threads.
pub fn partition_canonical_threads(g: &Graph, num_workers: usize, threads: usize) -> Partitioning {
    let assign = map_edges(g, threads, |(u, v)| {
        let (a, b) = if u <= v { (u, v) } else { (v, u) };
        worker_of_hash(pair_hash(a as u64, b as u64), num_workers)
    });
    Partitioning::from_edge_assignment_threads(g, num_workers, assign, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn canonical_is_order_insensitive() {
        let g = Graph::from_edges("c", 4, vec![(1, 2), (2, 1), (0, 3), (3, 0)], true);
        let p = partition_canonical(&g, 7);
        let find = |u, v| {
            let idx = g.edges().iter().position(|&e| e == (u, v)).unwrap();
            p.edge_worker[idx]
        };
        assert_eq!(find(1, 2), find(2, 1));
        assert_eq!(find(0, 3), find(3, 0));
    }

    #[test]
    fn random_is_order_sensitive_somewhere() {
        // across many reversed pairs, at least one maps differently
        let edges: Vec<(u32, u32)> = (0..50u32).flat_map(|i| vec![(i, i + 50), (i + 50, i)]).collect();
        let g = Graph::from_edges("r", 100, edges, true);
        let p = partition_random(&g, 8);
        let mut differs = false;
        for i in 0..50u32 {
            let a = g.edges().iter().position(|&e| e == (i, i + 50)).unwrap();
            let b = g.edges().iter().position(|&e| e == (i + 50, i)).unwrap();
            if p.edge_worker[a] != p.edge_worker[b] {
                differs = true;
                break;
            }
        }
        assert!(differs);
    }

    #[test]
    fn spreads_edges_roughly_uniformly() {
        let mut rng = crate::util::rng::Rng::new(44);
        let g = crate::graph::gen::erdos::generate("u", 500, 8000, true, &mut rng);
        let p = partition_random(&g, 8);
        let expect = 8000.0 / 8.0;
        for &c in &p.edges_per_worker {
            assert!((c as f64 - expect).abs() < expect * 0.2, "{:?}", p.edges_per_worker);
        }
    }
}
