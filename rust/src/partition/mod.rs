//! Partitioning strategies (§3.3, Table 2).
//!
//! All strategies are *vertex-cut*: they assign each **edge** to one of
//! `|W|` workers; a vertex is then replicated onto every worker holding
//! one of its incident edges, with one replica designated the master
//! (GAS semantics, §3.2.1). The inventory matches Table 2:
//!
//! | PSID | Strategy          | Engine      | Module        |
//! |------|-------------------|-------------|---------------|
//! | 0    | 1DSrc             | GraphX      | [`oned`]      |
//! | 1    | 1DDst (custom)    | —           | [`oned`]      |
//! | 2    | Random            | GraphX      | [`random`]    |
//! | 3    | Canonical Random  | GraphX      | [`random`]    |
//! | 4    | 2D Edge Partition | GraphX      | [`twod`]      |
//! | 5    | Hybrid            | PowerLyra   | [`hybrid`]    |
//! | 6    | Oblivious         | PowerGraph  | [`oblivious`] |
//! | 7-10 | HDRF λ∈{10,20,50,100} | PowerGraph | [`hdrf`]  |
//! | 11   | Ginger            | PowerLyra   | [`ginger`]    |
//!
//! Oblivious (PSID 6) is implemented but excluded from the default
//! inventory — the paper observed it "sometimes fails to utilize all
//! workers" and dropped it (§3.3.2), leaving 11 strategies.

pub mod ginger;
pub mod hdrf;
pub mod hybrid;
pub mod metrics;
pub mod oblivious;
pub mod oned;
pub mod random;
pub mod twod;

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::graph::{Graph, VertexId};
use crate::util::pool;
use crate::util::rng::hash_u64;

/// Target edges per chunk when a *single* partitioning call fans its
/// per-edge work over the pool. Chunk boundaries are a pure function of
/// the edge count — identical at every thread count — and the chunk
/// results are concatenated (or merged order-independently) in chunk
/// order, so the produced [`Partitioning`] is byte-identical to the
/// sequential one.
pub(crate) const SINGLE_PARTITION_CHUNK_EDGES: usize = 16_384;

/// Apply a pure per-edge function over `g.edges()` in canonical order,
/// fanning fixed-size chunks over up to `threads` pool threads. The
/// chunks are concatenated in chunk order, so the result is the exact
/// vector the sequential `edges().iter().map(f).collect()` produces —
/// the backbone of every stateless hash strategy's parallel path.
pub(crate) fn map_edges<F>(g: &Graph, threads: usize, f: F) -> Vec<u16>
where
    F: Fn((VertexId, VertexId)) -> u16 + Sync,
{
    let edges = g.edges();
    if threads.max(1) <= 1 || edges.len() < 2 * SINGLE_PARTITION_CHUNK_EDGES {
        return edges.iter().map(|&e| f(e)).collect();
    }
    let n_chunks = crate::util::div_ceil(edges.len(), SINGLE_PARTITION_CHUNK_EDGES);
    let parts = pool::parallel_map(threads, n_chunks, |k| {
        let lo = k * SINGLE_PARTITION_CHUNK_EDGES;
        let hi = (lo + SINGLE_PARTITION_CHUNK_EDGES).min(edges.len());
        edges[lo..hi].iter().map(|&e| f(e)).collect::<Vec<u16>>()
    });
    let mut out = Vec::with_capacity(edges.len());
    for p in parts {
        out.extend(p);
    }
    out
}

/// A partitioning strategy identifier (the paper's PSID column).
pub type StrategyId = usize;

/// The strategy inventory.
///
/// `Ord` follows declaration order (with HDRF ordered by λ) so the
/// strategy itself can key ordered maps — e.g. the execution-log time
/// index — without going through a PSID (partial: non-inventory λ) or
/// an allocated name.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Strategy {
    /// PSID 0 — hash of the source vertex id.
    OneDSrc,
    /// PSID 1 — hash of the destination vertex id (the paper's custom).
    OneDDst,
    /// PSID 2 — order-sensitive 2-D hash (Cantor pairing).
    Random,
    /// PSID 3 — order-insensitive 2-D hash.
    CanonicalRandom,
    /// PSID 4 — 2-D grid of workers, one hash per endpoint.
    TwoD,
    /// PSID 5 — PowerLyra hybrid (degree-threshold differentiated).
    Hybrid,
    /// PSID 6 — PowerGraph greedy vertex-cut (excluded from inventory).
    Oblivious,
    /// PSID 7..10 — HDRF with λ.
    Hdrf(u32),
    /// PSID 11 — PowerLyra Ginger.
    Ginger,
}

impl Strategy {
    /// The 11 strategies of the paper's inventory, in PSID order, as a
    /// const array — the allocation-free form used by the encoding and
    /// selection hot paths (one `encode` + `select` per candidate must
    /// not allocate an inventory vector each call).
    pub const INVENTORY: [Strategy; 11] = [
        Strategy::OneDSrc,
        Strategy::OneDDst,
        Strategy::Random,
        Strategy::CanonicalRandom,
        Strategy::TwoD,
        Strategy::Hybrid,
        Strategy::Hdrf(10),
        Strategy::Hdrf(20),
        Strategy::Hdrf(50),
        Strategy::Hdrf(100),
        Strategy::Ginger,
    ];

    /// The inventory as a `Vec` (see [`Strategy::INVENTORY`]).
    pub fn inventory() -> Vec<Strategy> {
        Self::INVENTORY.to_vec()
    }

    /// All 12 implemented strategies (inventory + Oblivious).
    pub fn all() -> Vec<Strategy> {
        let mut v = Self::inventory();
        v.insert(6, Strategy::Oblivious);
        v
    }

    /// The paper's PSID, if this strategy has one. Only the four
    /// inventory λ values of HDRF carry a PSID; any other `Hdrf(λ)` is
    /// a legal, runnable strategy without a column in the paper's
    /// tables, so it answers `None` here instead of panicking.
    pub fn try_psid(&self) -> Option<StrategyId> {
        Some(match self {
            Strategy::OneDSrc => 0,
            Strategy::OneDDst => 1,
            Strategy::Random => 2,
            Strategy::CanonicalRandom => 3,
            Strategy::TwoD => 4,
            Strategy::Hybrid => 5,
            Strategy::Oblivious => 6,
            Strategy::Hdrf(10) => 7,
            Strategy::Hdrf(20) => 8,
            Strategy::Hdrf(50) => 9,
            Strategy::Hdrf(100) => 10,
            Strategy::Hdrf(_) => return None,
            Strategy::Ginger => 11,
        })
    }

    /// The paper's PSID. Panics on a non-inventory HDRF λ — callers
    /// that can meet arbitrary strategies route through
    /// [`Strategy::try_psid`] instead.
    pub fn psid(&self) -> StrategyId {
        self.try_psid().unwrap_or_else(|| match self {
            Strategy::Hdrf(l) => panic!("non-inventory HDRF λ={l}"),
            _ => unreachable!("every non-HDRF strategy has a PSID"),
        })
    }

    /// Short name (paper's italic alias). Static for every variant
    /// except parameterised HDRF, so the common case allocates nothing.
    pub fn name(&self) -> Cow<'static, str> {
        match self {
            Strategy::OneDSrc => Cow::Borrowed("1DSrc"),
            Strategy::OneDDst => Cow::Borrowed("1DDst"),
            Strategy::Random => Cow::Borrowed("Random"),
            Strategy::CanonicalRandom => Cow::Borrowed("Cano"),
            Strategy::TwoD => Cow::Borrowed("2D"),
            Strategy::Hybrid => Cow::Borrowed("Hybrid"),
            Strategy::Oblivious => Cow::Borrowed("Oblivious"),
            Strategy::Hdrf(l) => Cow::Owned(format!("HDRF{l}")),
            Strategy::Ginger => Cow::Borrowed("Ginger"),
        }
    }

    /// Parse a strategy from its short name. Any `HDRF<λ>` parses —
    /// non-inventory λ values are legal, runnable strategies (they just
    /// carry no PSID; see [`Strategy::try_psid`]).
    pub fn by_name(name: &str) -> Option<Strategy> {
        let name = name.trim();
        if let Some(s) = Self::all().into_iter().find(|s| s.name().eq_ignore_ascii_case(name)) {
            return Some(s);
        }
        match name.get(..4) {
            Some(prefix) if prefix.eq_ignore_ascii_case("hdrf") => {
                name[4..].parse::<u32>().ok().map(Strategy::Hdrf)
            }
            _ => None,
        }
    }

    /// Run the strategy with up to [`pool::default_threads`] threads
    /// speeding up this *single* partitioning call. The result is
    /// byte-identical to the sequential computation (pinned by
    /// `tests/intra_equivalence.rs`), and the pool's budget arbiter
    /// keeps nested fan-outs (e.g. `warm_parallel` over many pairs)
    /// from oversubscribing — inner calls simply run inline when the
    /// budget is spent.
    pub fn partition(&self, g: &Graph, num_workers: usize) -> Partitioning {
        self.partition_with_threads(g, num_workers, pool::default_threads())
    }

    /// Run the strategy using up to `threads` pool threads for the
    /// per-edge work of this one call. Stateless hash strategies
    /// parallelize their whole edge map; the stateful streaming
    /// partitioners (HDRF/Ginger/Oblivious) keep their sequential core
    /// byte-identical and parallelize the replica/master derivation
    /// ([`Partitioning::from_edge_assignment_threads`]). `threads ≤ 1`
    /// is the fully sequential reference path.
    pub fn partition_with_threads(
        &self,
        g: &Graph,
        num_workers: usize,
        threads: usize,
    ) -> Partitioning {
        let t = threads;
        match self {
            Strategy::OneDSrc => oned::partition_src_threads(g, num_workers, t),
            Strategy::OneDDst => oned::partition_dst_threads(g, num_workers, t),
            Strategy::Random => random::partition_random_threads(g, num_workers, t),
            Strategy::CanonicalRandom => random::partition_canonical_threads(g, num_workers, t),
            Strategy::TwoD => twod::partition_threads(g, num_workers, t),
            Strategy::Hybrid => {
                hybrid::partition_threads(g, num_workers, hybrid::DEFAULT_THRESHOLD, t)
            }
            Strategy::Oblivious => oblivious::partition_threads(g, num_workers, t),
            Strategy::Hdrf(l) => hdrf::partition_threads(g, num_workers, *l as f64, t),
            Strategy::Ginger => {
                ginger::partition_threads(g, num_workers, hybrid::DEFAULT_THRESHOLD, t)
            }
        }
    }
}

/// The result of partitioning: a worker per stored edge, plus derived
/// per-worker structures consumed by the GAS engine and the metrics.
#[derive(Clone, Debug)]
pub struct Partitioning {
    pub num_workers: usize,
    /// Worker id per edge, indexed like `graph.edges()`.
    pub edge_worker: Vec<u16>,
    /// Edge count per worker.
    pub edges_per_worker: Vec<usize>,
    /// For each vertex, the sorted list of workers holding a replica.
    pub replicas: Vec<Vec<u16>>,
    /// Master worker per vertex (hash-designated among the replicas;
    /// isolated vertices get `hash(v) % |W|`).
    pub master: Vec<u16>,
}

impl Partitioning {
    /// Derive replica/master structure from a per-edge assignment
    /// (sequential reference path — see
    /// [`Partitioning::from_edge_assignment_threads`]).
    pub fn from_edge_assignment(g: &Graph, num_workers: usize, edge_worker: Vec<u16>) -> Self {
        Self::from_edge_assignment_threads(g, num_workers, edge_worker, 1)
    }

    /// Derive replica/master structure from a per-edge assignment,
    /// fanning the per-edge scan over up to `threads` pool threads.
    ///
    /// The parallel path computes per-chunk worker edge counts (integer
    /// sums — order-independent) and per-vertex replica *bitsets*
    /// (OR-merged — a set union, also order-independent), then extracts
    /// the sorted replica lists and masters exactly as the sequential
    /// scan would: ascending bit extraction equals
    /// `sort_unstable`-then-dedup of the insertion-order lists, and the
    /// master formula reads only the sorted list. The result is
    /// therefore **byte-identical** at every thread count. Graphs below
    /// [`SINGLE_PARTITION_CHUNK_EDGES`]×2 edges and partitionings over
    /// 64 workers (no single-word bitset) take the sequential path.
    pub fn from_edge_assignment_threads(
        g: &Graph,
        num_workers: usize,
        edge_worker: Vec<u16>,
        threads: usize,
    ) -> Self {
        assert_eq!(edge_worker.len(), g.num_edges());
        assert!(num_workers > 0 && num_workers <= u16::MAX as usize);
        let n = g.num_vertices();
        let edges = g.edges();
        if threads.max(1) > 1
            && num_workers <= 64
            && edges.len() >= 2 * SINGLE_PARTITION_CHUNK_EDGES
        {
            let n_chunks = crate::util::div_ceil(edges.len(), SINGLE_PARTITION_CHUNK_EDGES);
            let ew = &edge_worker;
            let parts = pool::parallel_map(threads, n_chunks, |k| {
                let lo = k * SINGLE_PARTITION_CHUNK_EDGES;
                let hi = (lo + SINGLE_PARTITION_CHUNK_EDGES).min(edges.len());
                let mut counts = vec![0usize; num_workers];
                let mut bits = vec![0u64; n];
                for (e, &(u, v)) in edges[lo..hi].iter().enumerate() {
                    let w = ew[lo + e];
                    debug_assert!((w as usize) < num_workers);
                    counts[w as usize] += 1;
                    bits[u as usize] |= 1u64 << w;
                    bits[v as usize] |= 1u64 << w;
                }
                (counts, bits)
            });
            let mut edges_per_worker = vec![0usize; num_workers];
            let mut bits = vec![0u64; n];
            for (counts, b) in parts {
                for (t, c) in edges_per_worker.iter_mut().zip(counts) {
                    *t += c;
                }
                for (t, x) in bits.iter_mut().zip(b) {
                    *t |= x;
                }
            }
            let mut replicas: Vec<Vec<u16>> = Vec::with_capacity(n);
            let mut master = vec![0u16; n];
            for (v, &word0) in bits.iter().enumerate() {
                let mut word = word0;
                let mut r = Vec::with_capacity(word.count_ones() as usize);
                while word != 0 {
                    r.push(word.trailing_zeros() as u16);
                    word &= word - 1;
                }
                let h = (hash_u64(v as u64) % num_workers as u64) as u16;
                master[v] = if r.is_empty() || r.contains(&h) {
                    h
                } else {
                    r[(hash_u64(v as u64 ^ 0x5bd1e995) as usize) % r.len()]
                };
                replicas.push(r);
            }
            return Partitioning { num_workers, edge_worker, edges_per_worker, replicas, master };
        }
        let mut edges_per_worker = vec![0usize; num_workers];
        let mut replicas: Vec<Vec<u16>> = vec![Vec::new(); n];
        for (e, &(u, v)) in edges.iter().enumerate() {
            let w = edge_worker[e];
            debug_assert!((w as usize) < num_workers);
            edges_per_worker[w as usize] += 1;
            for x in [u, v] {
                let r = &mut replicas[x as usize];
                if !r.contains(&w) {
                    r.push(w);
                }
            }
        }
        let mut master = vec![0u16; n];
        for v in 0..n {
            replicas[v].sort_unstable();
            let h = (hash_u64(v as u64) % num_workers as u64) as u16;
            master[v] = if replicas[v].is_empty() || replicas[v].contains(&h) {
                h
            } else {
                // deterministic pick among replicas, spread by hash
                replicas[v][(hash_u64(v as u64 ^ 0x5bd1e995) as usize) % replicas[v].len()]
            };
        }
        Partitioning { num_workers, edge_worker, edges_per_worker, replicas, master }
    }

    /// Number of mirror replicas (replicas excluding the master copy) of
    /// vertex `v`.
    pub fn num_mirrors(&self, v: VertexId) -> usize {
        let r = &self.replicas[v as usize];
        r.len().saturating_sub(if r.contains(&self.master[v as usize]) { 1 } else { 0 })
    }
}

/// Map a hash value to a worker id.
#[inline]
pub(crate) fn worker_of_hash(h: u64, num_workers: usize) -> u16 {
    (h % num_workers as u64) as u16
}

/// Thread-safe cache of partitioning results at a fixed worker count,
/// keyed by `(graph name, strategy)` — the strategy keys directly
/// (`Copy + Ord`, total for every variant), so probing the cache never
/// allocates a name and never hits the PSID panic non-inventory HDRF λ
/// values would cause.
///
/// Corpus construction runs every algorithm over every `(graph,
/// strategy)` pair; partitioning is the expensive, algorithm-independent
/// half of that work, so each pair is partitioned once and the
/// [`Partitioning`] shared behind an [`Arc`] with every task that needs
/// it. Graph names must be unique within one cache (true for the corpus
/// and for any single-graph use).
///
/// Strategies are deterministic, so if two threads race on the same
/// uncached key both compute bit-identical results; the first insert
/// wins and later callers share it. Callers that must guarantee
/// exactly-once computation (e.g. the corpus builder) pre-warm the
/// cache over the `(graph, strategy)` grid before fanning out.
pub struct PartitionCache {
    num_workers: usize,
    slots: Mutex<BTreeMap<(String, Strategy), Arc<Partitioning>>>,
}

impl PartitionCache {
    /// Create an empty cache for `num_workers`-way partitionings.
    pub fn new(num_workers: usize) -> Self {
        PartitionCache { num_workers, slots: Mutex::new(BTreeMap::new()) }
    }

    /// The worker count every cached partitioning targets.
    pub fn num_workers(&self) -> usize {
        self.num_workers
    }

    /// The cached partitioning of `g` under `s`, computing it on first
    /// use. The lock is *not* held while partitioning, so independent
    /// keys proceed in parallel.
    pub fn get_or_partition(&self, g: &Graph, s: Strategy) -> Arc<Partitioning> {
        let key = (g.name.clone(), s);
        if let Some(p) = self.slots.lock().unwrap().get(&key) {
            return Arc::clone(p);
        }
        let fresh = Arc::new(s.partition(g, self.num_workers));
        Arc::clone(self.slots.lock().unwrap().entry(key).or_insert(fresh))
    }

    /// Pre-warm the cache over `pairs` using up to `threads` pool
    /// threads ([`crate::util::pool::parallel_map`]).
    ///
    /// Already-cached pairs are skipped; the missing ones are
    /// partitioned in parallel and committed **in `pairs` order** (the
    /// caller's inventory order) under one lock acquisition, so the
    /// cache contents are independent of thread scheduling. Strategies
    /// are deterministic, so the parallelism cannot change any
    /// partitioning — only the wall-clock of this warming stage.
    pub fn warm_parallel(&self, threads: usize, pairs: &[(&Graph, Strategy)]) {
        let todo: Vec<usize> = {
            let slots = self.slots.lock().unwrap();
            (0..pairs.len())
                .filter(|&i| !slots.contains_key(&(pairs[i].0.name.clone(), pairs[i].1)))
                .collect()
        };
        let fresh = pool::parallel_map(threads, todo.len(), |j| {
            let (g, s) = pairs[todo[j]];
            Arc::new(s.partition(g, self.num_workers))
        });
        let mut slots = self.slots.lock().unwrap();
        for (&i, p) in todo.iter().zip(fresh) {
            let (g, s) = pairs[i];
            slots.entry((g.name.clone(), s)).or_insert(p);
        }
    }

    /// Number of distinct `(graph, strategy)` pairs cached so far.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// True when nothing has been partitioned yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn path_graph() -> Graph {
        Graph::from_edges("p", 5, vec![(0, 1), (1, 2), (2, 3), (3, 4)], true)
    }

    #[test]
    fn inventory_matches_table2() {
        let inv = Strategy::inventory();
        assert_eq!(inv.len(), 11);
        let psids: Vec<usize> = inv.iter().map(|s| s.psid()).collect();
        assert_eq!(psids, vec![0, 1, 2, 3, 4, 5, 7, 8, 9, 10, 11]);
        assert!(!inv.contains(&Strategy::Oblivious));
        assert_eq!(Strategy::all().len(), 12);
        assert_eq!(Strategy::Oblivious.psid(), 6);
    }

    #[test]
    fn names_roundtrip() {
        for s in Strategy::all() {
            assert_eq!(Strategy::by_name(&s.name()), Some(s), "{}", s.name());
        }
        assert_eq!(Strategy::by_name("hdrf50"), Some(Strategy::Hdrf(50)));
        assert_eq!(Strategy::by_name("bogus"), None);
    }

    /// Non-inventory HDRF λ values are runnable strategies without a
    /// PSID: `try_psid` answers `None` (regression — `psid()` used to
    /// be the only accessor and panicked), the name is still total, and
    /// the partition cache accepts them.
    #[test]
    fn non_inventory_hdrf_lambda_has_no_psid_but_works() {
        let odd = Strategy::Hdrf(42);
        assert_eq!(odd.try_psid(), None);
        assert_eq!(odd.name(), "HDRF42");
        assert_eq!(Strategy::by_name("HDRF42"), Some(odd));
        for s in Strategy::all() {
            assert_eq!(s.try_psid(), Some(s.psid()), "{}", s.name());
        }
        // the cache key is the strategy itself (total Ord), so caching
        // cannot panic
        let mut rng = crate::util::rng::Rng::new(36);
        let g = crate::graph::gen::erdos::generate("odd-l", 80, 300, true, &mut rng);
        let cache = PartitionCache::new(4);
        let a = cache.get_or_partition(&g, odd);
        assert_eq!(a.edge_worker, odd.partition(&g, 4).edge_worker);
        assert!(Arc::ptr_eq(&a, &cache.get_or_partition(&g, odd)));
        // distinct λ values get distinct cache slots
        cache.get_or_partition(&g, Strategy::Hdrf(50));
        assert_eq!(cache.len(), 2);
    }

    /// `Ord` on Strategy follows (declaration order, λ) — the contract
    /// the execution-log time index relies on for its map key.
    #[test]
    fn strategy_ordering_is_total_and_stable() {
        assert!(Strategy::OneDSrc < Strategy::OneDDst);
        assert!(Strategy::Hdrf(10) < Strategy::Hdrf(20));
        assert!(Strategy::Hdrf(100) < Strategy::Ginger);
        let mut v = Strategy::inventory();
        v.sort_unstable();
        assert_eq!(v, Strategy::inventory(), "inventory is already in Ord order");
    }

    #[test]
    fn replica_and_master_derivation() {
        let g = path_graph();
        // all edges on worker 0 except edge (2,3) on worker 1
        let p = Partitioning::from_edge_assignment(&g, 2, vec![0, 0, 1, 0]);
        assert_eq!(p.edges_per_worker, vec![3, 1]);
        assert_eq!(p.replicas[2], vec![0, 1], "vertex 2 spans both workers");
        assert_eq!(p.replicas[0], vec![0]);
        // master of a replicated vertex is one of its replicas
        assert!(p.replicas[2].contains(&p.master[2]));
        assert_eq!(p.num_mirrors(2), 1);
        assert_eq!(p.num_mirrors(0), 0);
    }

    /// The parallel replica/master derivation (per-chunk bitsets,
    /// OR-merge) must be byte-identical to the sequential scan on a
    /// graph large enough to actually take the chunked path.
    #[test]
    fn parallel_edge_assignment_matches_sequential() {
        let mut rng = crate::util::rng::Rng::new(38);
        let g = crate::graph::gen::erdos::generate("big", 3000, 40_000, true, &mut rng);
        assert!(g.num_edges() >= 2 * SINGLE_PARTITION_CHUNK_EDGES, "graph must exceed threshold");
        let assign: Vec<u16> =
            (0..g.num_edges()).map(|i| (i % 8) as u16).collect();
        let seq = Partitioning::from_edge_assignment_threads(&g, 8, assign.clone(), 1);
        for threads in [2usize, 4, 8] {
            let par = Partitioning::from_edge_assignment_threads(&g, 8, assign.clone(), threads);
            assert_eq!(par.edge_worker, seq.edge_worker, "{threads} threads");
            assert_eq!(par.edges_per_worker, seq.edges_per_worker, "{threads} threads");
            assert_eq!(par.replicas, seq.replicas, "{threads} threads");
            assert_eq!(par.master, seq.master, "{threads} threads");
        }
    }

    #[test]
    fn all_strategies_produce_valid_assignments() {
        let mut rng = crate::util::rng::Rng::new(33);
        let g = crate::graph::gen::erdos::generate("t", 200, 1000, true, &mut rng);
        for s in Strategy::all() {
            let p = s.partition(&g, 8);
            assert_eq!(p.edge_worker.len(), g.num_edges(), "{}", s.name());
            assert!(p.edge_worker.iter().all(|&w| (w as usize) < 8), "{}", s.name());
            assert_eq!(p.edges_per_worker.iter().sum::<usize>(), g.num_edges());
        }
    }

    #[test]
    fn strategies_are_deterministic() {
        let mut rng = crate::util::rng::Rng::new(34);
        let g = crate::graph::gen::erdos::generate("t", 100, 400, false, &mut rng);
        for s in Strategy::all() {
            let a = s.partition(&g, 4).edge_worker;
            let b = s.partition(&g, 4).edge_worker;
            assert_eq!(a, b, "{} must be deterministic", s.name());
        }
    }

    /// The cache must hand back exactly what a fresh partition call
    /// produces — edge assignment, masters and derived metrics — for
    /// every inventory strategy, and share one allocation per key.
    #[test]
    fn cache_matches_fresh_partition() {
        let mut rng = crate::util::rng::Rng::new(35);
        let g = crate::graph::gen::erdos::generate("cache-t", 150, 700, true, &mut rng);
        let cache = PartitionCache::new(8);
        assert!(cache.is_empty());
        for s in Strategy::inventory() {
            let cached = cache.get_or_partition(&g, s);
            let fresh = s.partition(&g, 8);
            assert_eq!(cached.edge_worker, fresh.edge_worker, "{}", s.name());
            assert_eq!(cached.master, fresh.master, "{}", s.name());
            assert_eq!(cached.replicas, fresh.replicas, "{}", s.name());
            let mc = metrics::PartitionMetrics::of(&g, &cached);
            let mf = metrics::PartitionMetrics::of(&g, &fresh);
            assert_eq!(mc.replication_factor, mf.replication_factor, "{}", s.name());
            assert_eq!(mc.edge_balance, mf.edge_balance, "{}", s.name());
            // the second lookup is a hit on the same shared allocation
            assert!(Arc::ptr_eq(&cached, &cache.get_or_partition(&g, s)), "{}", s.name());
        }
        assert_eq!(cache.len(), Strategy::inventory().len());
        assert_eq!(cache.num_workers(), 8);
    }

    /// Parallel pre-warming must produce the identical cache contents
    /// at every thread count — same edge assignments, same masters —
    /// and skip pairs that are already cached.
    #[test]
    fn warm_parallel_matches_sequential_at_every_thread_count() {
        let mut rng = crate::util::rng::Rng::new(37);
        let g1 = crate::graph::gen::erdos::generate("warm-a", 120, 500, true, &mut rng);
        let g2 = crate::graph::gen::erdos::generate("warm-b", 90, 350, false, &mut rng);
        let pairs: Vec<(&Graph, Strategy)> = [&g1, &g2]
            .into_iter()
            .flat_map(|g| Strategy::inventory().into_iter().map(move |s| (g, s)))
            .collect();
        let reference = PartitionCache::new(4);
        for &(g, s) in &pairs {
            reference.get_or_partition(g, s);
        }
        for threads in [1usize, 2, 4, 8] {
            let cache = PartitionCache::new(4);
            // pre-seed one slot: warming must keep it (first insert wins)
            let seeded = cache.get_or_partition(&g1, Strategy::Random);
            cache.warm_parallel(threads, &pairs);
            assert_eq!(cache.len(), pairs.len(), "{threads} threads");
            assert!(Arc::ptr_eq(&seeded, &cache.get_or_partition(&g1, Strategy::Random)));
            for &(g, s) in &pairs {
                let got = cache.get_or_partition(g, s);
                let want = reference.get_or_partition(g, s);
                assert_eq!(got.edge_worker, want.edge_worker, "{} {}", g.name, s.name());
                assert_eq!(got.master, want.master, "{} {}", g.name, s.name());
            }
        }
    }
}
