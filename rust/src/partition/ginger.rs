//! PowerLyra Ginger partitioning (PSID 11, §3.3.3-ii).
//!
//! Like Hybrid, Ginger differentiates by in-degree, but the low-degree
//! side replaces the hash with a Fennel-style streaming score
//! (paper Eq. 2): vertex `v` (with all of its in-edges) goes to the
//! worker maximising
//!
//! ```text
//! Ginger(v, w) = |N_in(v) ∩ V_w| − ½ (|V_w| + (|V|/|E|)·|E_w|)
//! ```
//!
//! The first term pulls `v` toward workers already owning its
//! in-neighbours (suppressing replication); the second penalises
//! crowded workers (load balance). High-degree vertices fall back to
//! source hashing exactly as in Hybrid.

use crate::graph::Graph;
use crate::util::rng::hash_u64;

use super::{map_edges, worker_of_hash, Partitioning};

/// PSID 11 — Ginger with the given in-degree threshold for the
/// low/high-degree split (the paper pairs it with Hybrid's threshold).
/// Sequential reference path.
pub fn partition(g: &Graph, num_workers: usize, threshold: usize) -> Partitioning {
    partition_threads(g, num_workers, threshold, 1)
}

/// Ginger with up to `threads` pool threads. The streaming Fennel
/// owner loop is inherently order-dependent and stays sequential
/// byte-for-byte; the *final* per-edge assignment (a pure function of
/// the finished `owner` table) and the replica/master derivation fan
/// over the pool — byte-identical by construction.
pub fn partition_threads(
    g: &Graph,
    num_workers: usize,
    threshold: usize,
    threads: usize,
) -> Partitioning {
    let n = g.num_vertices();
    let ratio = if g.num_edges() > 0 {
        n as f64 / g.num_edges() as f64
    } else {
        1.0
    };
    // owner[v] = worker that received v's in-edges (low-degree only)
    let mut owner: Vec<u16> = vec![u16::MAX; n];
    let mut vcount = vec![0usize; num_workers];
    let mut ecount = vec![0usize; num_workers];
    let mut neighbor_hits = vec![0usize; num_workers];
    let mut touched: Vec<usize> = Vec::new();
    for v in g.vertices() {
        let indeg = g.in_degree(v);
        if indeg > threshold {
            continue; // high-degree: handled by source hash below
        }
        // count in-neighbours already owned per worker
        for &u in g.in_neighbors(v) {
            let w = owner[u as usize];
            if w != u16::MAX {
                if neighbor_hits[w as usize] == 0 {
                    touched.push(w as usize);
                }
                neighbor_hits[w as usize] += 1;
            }
        }
        let mut best_w = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for w in 0..num_workers {
            let score = neighbor_hits[w] as f64
                - 0.5 * (vcount[w] as f64 + ratio * ecount[w] as f64);
            if score > best_score {
                best_score = score;
                best_w = w;
            }
        }
        for &w in &touched {
            neighbor_hits[w] = 0;
        }
        touched.clear();
        owner[v as usize] = best_w as u16;
        vcount[best_w] += 1;
        ecount[best_w] += indeg;
    }
    let assign = map_edges(g, threads, |(u, v)| {
        if g.in_degree(v) <= threshold {
            owner[v as usize]
        } else {
            worker_of_hash(hash_u64(u as u64), num_workers)
        }
    });
    Partitioning::from_edge_assignment_threads(g, num_workers, assign, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::metrics::PartitionMetrics;

    #[test]
    fn all_low_degree_vertices_get_owner() {
        let mut rng = crate::util::rng::Rng::new(90);
        let g = crate::graph::gen::erdos::generate("t", 200, 800, true, &mut rng);
        let p = partition(&g, 8, 1_000);
        assert_eq!(p.edge_worker.len(), g.num_edges());
        assert!(p.edge_worker.iter().all(|&w| (w as usize) < 8));
    }

    #[test]
    fn colocates_neighborhoods_better_than_random() {
        // community-structured small world: Ginger should achieve lower
        // replication than the random 2-D hash
        let mut rng = crate::util::rng::Rng::new(91);
        let g = crate::graph::gen::smallworld::generate("sw", 800, 4800, 0.05, &mut rng);
        let mg = PartitionMetrics::of(&g, &partition(&g, 16, 100));
        let mr =
            PartitionMetrics::of(&g, &crate::partition::random::partition_random(&g, 16));
        assert!(
            mg.replication_factor < mr.replication_factor,
            "ginger {} < random {}",
            mg.replication_factor,
            mr.replication_factor
        );
    }

    #[test]
    fn balance_term_prevents_collapse() {
        // without the ½(|V_w| + ...) term every vertex would chase its
        // neighbours onto worker 0; the penalty must spread ownership.
        let mut rng = crate::util::rng::Rng::new(92);
        let g = crate::graph::gen::smallworld::generate("sw", 400, 2000, 0.02, &mut rng);
        let p = partition(&g, 8, 1_000);
        let m = PartitionMetrics::of(&g, &p);
        assert_eq!(m.workers_used, 8, "all workers used: {:?}", p.edges_per_worker);
        assert!(m.edge_balance < 2.0, "imbalance {}", m.edge_balance);
    }

    #[test]
    fn high_degree_falls_back_to_source_hash() {
        let edges: Vec<(u32, u32)> = (1..=30).map(|u| (u as u32, 0)).collect();
        let g = crate::graph::Graph::from_edges("hub", 31, edges, true);
        let p = partition(&g, 4, 5);
        let by_src = crate::partition::oned::partition_src(&g, 4);
        assert_eq!(p.edge_worker, by_src.edge_worker);
    }
}
