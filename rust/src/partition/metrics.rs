//! Partition-quality metrics: the quantities the partitioning literature
//! (and §1/§3.3 of the paper) uses to characterise a strategy —
//! replication factor, load balance, worker utilisation.

use crate::graph::Graph;

use super::Partitioning;

/// Quality summary of one partitioning.
#[derive(Clone, Copy, Debug)]
pub struct PartitionMetrics {
    /// Σ_v |replicas(v)| / |V| — the paper's "ratio of the number of the
    /// replicated vertex to the number of the original vertex".
    pub replication_factor: f64,
    /// max_w |E_w| / (|E| / |W|): 1.0 = perfect edge balance.
    pub edge_balance: f64,
    /// max_w |V_w| / (Σ_w |V_w| / |W|): vertex-replica balance.
    pub vertex_balance: f64,
    /// Number of workers that received at least one edge.
    pub workers_used: usize,
    /// Total mirror count Σ_v (|replicas(v)| − 1)⁺ — proportional to
    /// gather/apply network traffic under GAS.
    pub total_mirrors: usize,
}

impl PartitionMetrics {
    /// Compute all metrics.
    pub fn of(g: &Graph, p: &Partitioning) -> Self {
        let n = g.num_vertices().max(1);
        let mut replica_sum = 0usize;
        let mut mirrors = 0usize;
        let mut vcount = vec![0usize; p.num_workers];
        for v in g.vertices() {
            let r = p.replicas[v as usize].len();
            replica_sum += r;
            mirrors += r.saturating_sub(1);
            for &w in &p.replicas[v as usize] {
                vcount[w as usize] += 1;
            }
        }
        let edges = g.num_edges();
        let max_e = p.edges_per_worker.iter().copied().max().unwrap_or(0);
        let mean_e = edges as f64 / p.num_workers as f64;
        let max_v = vcount.iter().copied().max().unwrap_or(0);
        let mean_v = replica_sum as f64 / p.num_workers as f64;
        PartitionMetrics {
            replication_factor: replica_sum as f64 / n as f64,
            edge_balance: if edges == 0 { 1.0 } else { max_e as f64 / mean_e },
            vertex_balance: if replica_sum == 0 { 1.0 } else { max_v as f64 / mean_v },
            workers_used: p.edges_per_worker.iter().filter(|&&c| c > 0).count(),
            total_mirrors: mirrors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::partition::Partitioning;

    #[test]
    fn single_worker_degenerate() {
        let g = Graph::from_edges("s", 3, vec![(0, 1), (1, 2)], true);
        let p = Partitioning::from_edge_assignment(&g, 1, vec![0, 0]);
        let m = PartitionMetrics::of(&g, &p);
        assert!((m.replication_factor - 1.0).abs() < 1e-12);
        assert_eq!(m.edge_balance, 1.0);
        assert_eq!(m.workers_used, 1);
        assert_eq!(m.total_mirrors, 0);
    }

    #[test]
    fn split_vertex_counts_as_replica() {
        let g = Graph::from_edges("s", 3, vec![(0, 1), (1, 2)], true);
        // edge 0 on worker 0, edge 1 on worker 1 → vertex 1 replicated
        let p = Partitioning::from_edge_assignment(&g, 2, vec![0, 1]);
        let m = PartitionMetrics::of(&g, &p);
        // replicas: v0→1, v1→2, v2→1 ⇒ rf = 4/3
        assert!((m.replication_factor - 4.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.total_mirrors, 1);
        assert_eq!(m.edge_balance, 1.0);
    }

    #[test]
    fn imbalance_detected() {
        let g = Graph::from_edges("i", 4, vec![(0, 1), (0, 2), (0, 3)], true);
        let p = Partitioning::from_edge_assignment(&g, 3, vec![0, 0, 0]);
        let m = PartitionMetrics::of(&g, &p);
        assert_eq!(m.workers_used, 1);
        assert!((m.edge_balance - 3.0).abs() < 1e-12, "3 edges on 1 of 3 workers");
    }
}
