//! PowerGraph Greedy vertex-cut, "Oblivious" variant (PSID 6, §3.3.2-ii).
//!
//! Edges are streamed one by one; each placement greedily minimises new
//! vertex replicas while balancing edge counts, using only state
//! accumulated so far (no global degree knowledge — hence *oblivious*):
//!
//! 1. both endpoints already share a worker → least-loaded shared worker;
//! 2. both have replicas but disjoint → the worker set of the endpoint
//!    with the **higher partial degree** is kept intact (its vertex is
//!    likelier to keep growing, so we replicate the other one);
//! 3. exactly one endpoint has replicas → its least-loaded worker;
//! 4. neither → globally least-loaded worker.
//!
//! The paper excludes this strategy from the inventory after observing
//! it can leave workers idle; [`tests::can_underutilize_workers`]
//! reproduces that failure mode.

use crate::graph::Graph;

use super::Partitioning;

/// Compact per-vertex replica bitset (supports up to 1024 workers).
pub(crate) struct ReplicaSets {
    words: usize,
    bits: Vec<u64>,
}

impl ReplicaSets {
    pub(crate) fn new(n: usize, num_workers: usize) -> Self {
        assert!(num_workers <= 1024, "replica bitset supports ≤1024 workers");
        let words = crate::util::div_ceil(num_workers, 64);
        ReplicaSets { words, bits: vec![0u64; n * words] }
    }

    #[inline]
    pub(crate) fn contains(&self, v: u32, w: usize) -> bool {
        self.bits[v as usize * self.words + w / 64] >> (w % 64) & 1 == 1
    }

    #[inline]
    pub(crate) fn insert(&mut self, v: u32, w: usize) {
        self.bits[v as usize * self.words + w / 64] |= 1 << (w % 64);
    }

    /// First 64-bit word of `v`'s replica set — the whole set when the
    /// partitioning uses ≤ 64 workers (HDRF's register fast path).
    #[inline]
    pub(crate) fn word0(&self, v: u32) -> u64 {
        self.bits[v as usize * self.words]
    }

    #[inline]
    pub(crate) fn is_empty(&self, v: u32) -> bool {
        let s = v as usize * self.words;
        self.bits[s..s + self.words].iter().all(|&x| x == 0)
    }

    /// Iterate worker ids present for `v`.
    pub(crate) fn iter(&self, v: u32) -> impl Iterator<Item = usize> + '_ {
        let s = v as usize * self.words;
        let words = self.words;
        (0..words).flat_map(move |wi| {
            let mut word = self.bits[s + wi];
            std::iter::from_fn(move || {
                if word == 0 {
                    None
                } else {
                    let b = word.trailing_zeros() as usize;
                    word &= word - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

fn least_loaded(workers: impl Iterator<Item = usize>, load: &[usize]) -> Option<usize> {
    workers.min_by_key(|&w| (load[w], w))
}

/// PSID 6 — greedy Oblivious vertex-cut (sequential reference path).
pub fn partition(g: &Graph, num_workers: usize) -> Partitioning {
    partition_threads(g, num_workers, 1)
}

/// Oblivious with up to `threads` pool threads. The greedy placement
/// stream is order-dependent by design and stays sequential
/// byte-for-byte; only the replica/master derivation over the finished
/// assignment fans over the pool.
pub fn partition_threads(g: &Graph, num_workers: usize, threads: usize) -> Partitioning {
    let n = g.num_vertices();
    let mut replicas = ReplicaSets::new(n, num_workers);
    let mut load = vec![0usize; num_workers];
    let mut partial_deg = vec![0u32; n];
    let mut assign = Vec::with_capacity(g.num_edges());
    for &(u, v) in g.edges() {
        let shared = least_loaded(
            replicas.iter(u).filter(|&w| replicas.contains(v, w)),
            &load,
        );
        let w = if let Some(w) = shared {
            w
        } else {
            match (replicas.is_empty(u), replicas.is_empty(v)) {
                (false, false) => {
                    // disjoint sets: replicate the lower-partial-degree
                    // endpoint into the higher one's set
                    let keep = if partial_deg[u as usize] >= partial_deg[v as usize] { u } else { v };
                    least_loaded(replicas.iter(keep), &load).unwrap()
                }
                (false, true) => least_loaded(replicas.iter(u), &load).unwrap(),
                (true, false) => least_loaded(replicas.iter(v), &load).unwrap(),
                (true, true) => least_loaded(0..num_workers, &load).unwrap(),
            }
        };
        replicas.insert(u, w);
        replicas.insert(v, w);
        partial_deg[u as usize] += 1;
        partial_deg[v as usize] += 1;
        load[w] += 1;
        assign.push(w as u16);
    }
    Partitioning::from_edge_assignment_threads(g, num_workers, assign, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::metrics::PartitionMetrics;

    #[test]
    fn bitset_ops() {
        let mut r = ReplicaSets::new(4, 130);
        assert!(r.is_empty(2));
        r.insert(2, 0);
        r.insert(2, 64);
        r.insert(2, 129);
        assert!(r.contains(2, 64));
        assert!(!r.contains(2, 63));
        assert_eq!(r.iter(2).collect::<Vec<_>>(), vec![0, 64, 129]);
        assert!(r.is_empty(3));
    }

    #[test]
    fn lower_replication_than_random() {
        let mut rng = crate::util::rng::Rng::new(70);
        let g = crate::graph::gen::chung_lu::generate("t", 800, 8000, 2.1, true, &mut rng);
        let mo = PartitionMetrics::of(&g, &partition(&g, 16));
        let mr =
            PartitionMetrics::of(&g, &crate::partition::random::partition_random(&g, 16));
        assert!(
            mo.replication_factor < mr.replication_factor,
            "oblivious {} < random {}",
            mo.replication_factor,
            mr.replication_factor
        );
    }

    /// The failure mode the paper cites for dropping Oblivious: on a
    /// connected graph streamed in BFS-ish edge order, placements chase
    /// existing replicas and some workers may receive almost nothing.
    #[test]
    fn can_underutilize_workers() {
        // a star: every edge shares vertex 0, so rules 1/3 keep all edges
        // near vertex 0's replica set; balance only grows slowly.
        let edges: Vec<(u32, u32)> = (1..=64).map(|i| (0u32, i as u32)).collect();
        let g = crate::graph::Graph::from_edges("star", 65, edges, true);
        let p = partition(&g, 16);
        let used = p.edges_per_worker.iter().filter(|&&c| c > 0).count();
        assert!(used < 16, "star stream should not fill all workers, used={used}");
    }

    #[test]
    fn first_edge_goes_to_least_loaded() {
        let g = crate::graph::Graph::from_edges("e", 2, vec![(0, 1)], true);
        let p = partition(&g, 4);
        assert_eq!(p.edge_worker[0], 0, "empty loads tie-break to lowest id");
    }
}
