//! Table/figure renderers — one function per §5 artifact (DESIGN.md
//! experiment index). Each returns the rendered text so benches,
//! examples and the CLI share the exact same row generators.

use crate::algorithms::Algorithm;
use crate::dataset::split::TestSet;
use crate::engine::cluster::ClusterSpec;
use crate::etrm::EtrmBackend;
use crate::features::encoding::{table3_group, table4_group};
use crate::graph::datasets::DatasetSpec;
use crate::partition::Strategy;
use crate::util::error::{bail, Result};
use crate::util::stats::BoxPlot;
use crate::util::table::{f, Table};

use super::pipeline::{Evaluation, TaskEval};

/// Fig 1 — motivation: per-strategy execution times for the paper's five
/// example tasks; best/worst marked. Takes the raw log store so the
/// bench can regenerate it without training a model.
pub fn fig1_from_store(store: &crate::dataset::logs::LogStore) -> String {
    let cases: &[(&str, Algorithm)] = &[
        ("stanford", Algorithm::Apcn),
        ("stanford", Algorithm::Pr),
        ("gd-hu", Algorithm::Apcn),
        ("stanford", Algorithm::Tc),
        ("gd-hr", Algorithm::Apcn),
    ];
    let mut out = String::from("Fig 1 — execution time by partitioning strategy (s)\n");
    let mut header: Vec<String> = vec!["task".into()];
    header.extend(Strategy::inventory().iter().map(|s| s.name().into_owned()));
    let mut t = Table::new(header);
    for &(graph, algo) in cases {
        let times = store
            .times_of_task(graph, algo.name())
            .expect("the corpus covers every Fig 1 example task");
        let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let worst = times.iter().cloned().fold(0.0, f64::max);
        let mut row = vec![format!("{graph}/{}", algo.name())];
        for &x in &times {
            let mark = if x == best {
                "*" // the dotted bar
            } else if x == worst {
                "!" // the striped bar
            } else {
                ""
            };
            row.push(format!("{}{mark}", f(x, 3)));
        }
        t.row(row);
    }
    out.push_str(&t.render());
    out.push_str("(* = best strategy, ! = worst — note both differ per task)\n");
    out
}

/// Fig 1 from a full evaluation.
pub fn fig1(eval: &Evaluation) -> String {
    fig1_from_store(&eval.store)
}

/// Fig 4 — engine scalability: PR(10 iter) and TC on stanford with the
/// 2D strategy, workers ∈ {4, 8, 16, 32, 64}.
pub fn fig4(scale: f64, seed: u64) -> Result<String> {
    let g = DatasetSpec::by_name("stanford").unwrap().build(scale, seed);
    let mut t = Table::new(vec!["workers", "PR time (s)", "TC time (s)"]);
    for &w in &[4usize, 8, 16, 32, 64] {
        let cfg = ClusterSpec::with_workers(w);
        let p = Strategy::TwoD.partition(&g, w);
        let pr = Algorithm::Pr.simulate(&g, &p, &cfg).sim.total;
        let tc = Algorithm::Tc.simulate(&g, &p, &cfg).sim.total;
        t.row(vec![w.to_string(), f(pr, 4), f(tc, 4)]);
    }
    Ok(format!(
        "Fig 4 — scalability on stanford (scale {scale}), 2D partitioning\n{}",
        t.render()
    ))
}

/// Table 2 — the partitioning-strategy inventory.
pub fn table2() -> String {
    let mut t = Table::new(vec!["PSID", "Strategy", "Engine", "Method", "Target objects"]);
    let rows: &[(&str, &str, &str, &str, &str)] = &[
        ("0", "1DSrc", "GraphX", "1D-Hash", "-"),
        ("1", "1DDst", "(custom)", "1D-Hash", "-"),
        ("2", "Random", "GraphX", "2D-Hash", "-"),
        ("3", "Cano", "GraphX", "2D-Hash", "-"),
        ("4", "2D", "GraphX", "Two 1D-Hash", "-"),
        ("5", "Hybrid", "PowerLyra", "Hash & degree threshold", "Replication factor"),
        ("6", "Oblivious", "PowerGraph", "Greedy", "Replication factor (excluded)"),
        ("7-10", "HDRF λ∈{10,20,50,100}", "PowerGraph", "Greedy", "Replication & balance"),
        ("11", "Ginger", "PowerLyra", "Greedy", "Replication & balance"),
    ];
    for r in rows {
        t.row(vec![r.0, r.1, r.2, r.3, r.4]);
    }
    format!("Table 2 — partitioning strategies\n{}", t.render())
}

fn importance_table(
    eval: &Evaluation,
    title: &str,
    group: impl Fn(usize) -> Option<&'static str>,
) -> Result<String> {
    let EtrmBackend::Gbdt(model) = &eval.etrm.backend else {
        bail!("importance requires the GBDT backend");
    };
    let mut t = Table::new(vec!["Feature", "Gain importance", "Split importance"]);
    let mut rows = model.importance.grouped(group);
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (label, gain, splits) in rows {
        t.row(vec![label, f(gain, 4), splits.to_string()]);
    }
    Ok(format!("{title}\n{}", t.render()))
}

/// Table 3 — data-feature importance.
pub fn table3(eval: &Evaluation) -> Result<String> {
    importance_table(eval, "Table 3 — data features (gain/split importance)", table3_group)
}

/// Table 4 — algorithm-feature importance.
pub fn table4(eval: &Evaluation) -> Result<String> {
    importance_table(eval, "Table 4 — algorithm features (gain/split importance)", table4_group)
}

/// Fig 6 — cumulative ratio of the selected strategies' actual rank,
/// overall and per test set.
pub fn fig6(eval: &Evaluation) -> String {
    let mut header = vec!["set".to_string()];
    header.extend((1..=11).map(|r| format!("≤{r}")));
    let mut t = Table::new(header);
    let all: Vec<&TaskEval> = eval.tasks.iter().collect();
    let mut push = |label: &str, tasks: &[&TaskEval]| {
        let curve = Evaluation::cumulative_rank_ratio(tasks);
        let mut row = vec![label.to_string()];
        row.extend(curve.iter().map(|&x| f(x, 2)));
        t.row(row);
    };
    push("all", &all);
    for set in TestSet::all() {
        push(set.name(), &eval.of_set(set));
    }
    format!("Fig 6 — cumulative ratio of selected strategies' actual rank\n{}", t.render())
}

fn boxplot_row(label: &str, xs: &[f64]) -> Vec<String> {
    let b = BoxPlot::of(xs);
    vec![
        label.to_string(),
        f(b.min, 3),
        f(b.q1, 3),
        f(b.median, 3),
        f(b.q3, 3),
        f(b.max, 3),
        f(b.mean, 3),
    ]
}

/// Fig 7 — Score_best / Score_worst / Score_avg five-number summaries,
/// grouped by graph data and by algorithm.
pub fn fig7(eval: &Evaluation) -> String {
    let mut out = String::from("Fig 7 — evaluation score box plots\n");
    for (metric, pick) in [
        ("Score_best", 0usize),
        ("Score_worst", 1),
        ("Score_avg", 2),
    ] {
        let select = |t: &TaskEval| match pick {
            0 => t.scores.best,
            1 => t.scores.worst,
            _ => t.scores.avg,
        };
        out.push_str(&format!("\n{metric} by graph data (│ new graphs right of bar)\n"));
        let mut t = Table::new(vec!["graph", "min", "q1", "median", "q3", "max", "mean"]);
        for spec in crate::graph::datasets::CORPUS {
            let xs: Vec<f64> = eval
                .tasks
                .iter()
                .filter(|x| x.graph == spec.name)
                .map(select)
                .collect();
            let label =
                if spec.in_training { spec.name.to_string() } else { format!("{}│new", spec.name) };
            t.row(boxplot_row(&label, &xs));
        }
        out.push_str(&t.render());
        out.push_str(&format!("\n{metric} by algorithm\n"));
        let mut t = Table::new(vec!["algorithm", "min", "q1", "median", "q3", "max", "mean"]);
        for a in Algorithm::all() {
            let xs: Vec<f64> =
                eval.tasks.iter().filter(|x| x.algorithm == a).map(select).collect();
            let label = if Algorithm::heldout().contains(&a) {
                format!("{}│new", a.name())
            } else {
                a.name().to_string()
            };
            t.row(boxplot_row(&label, &xs));
        }
        out.push_str(&t.render());
    }
    out
}

/// Table 6 — mean score summary (all cases + per test set).
pub fn table6(eval: &Evaluation) -> String {
    let mut t = Table::new(vec!["", "Score_best", "Score_worst", "Score_avg"]);
    let all: Vec<&TaskEval> = eval.tasks.iter().collect();
    let (b, w, a) = Evaluation::mean_scores(&all);
    t.row(vec!["All cases".to_string(), f(b, 4), f(w, 4), f(a, 4)]);
    for set in TestSet::all() {
        let tasks = eval.of_set(set);
        let (b, w, a) = Evaluation::mean_scores(&tasks);
        t.row(vec![format!("Test set {}", set.name()), f(b, 4), f(w, 4), f(a, 4)]);
    }
    format!("Table 6 — score summary\n{}", t.render())
}

/// Fig 8 — histogram of tasks by distance from T_best: ETRM selection
/// vs the mean of 5 random picks per task.
pub fn fig8(eval: &Evaluation) -> String {
    // bins over Score_best = T_best/T_sel: within 5%, 5-20%, 20-50%, >50%
    let edges = [0.95, 0.8, 0.5, 0.0];
    let labels = ["within 5%", "5-20% slower", "20-50% slower", ">50% slower"];
    let bucket = |score: f64| -> usize {
        if score >= edges[0] {
            0
        } else if score >= edges[1] {
            1
        } else if score >= edges[2] {
            2
        } else {
            3
        }
    };
    let mut etrm = [0usize; 4];
    for t in &eval.tasks {
        etrm[bucket(t.scores.best)] += 1;
    }
    let mut random = [0usize; 4];
    for score in eval.random_baseline_scores(eval.config.seed ^ 0xf18) {
        random[bucket(score)] += 1;
    }
    let mut t = Table::new(vec!["distance from T_best", "ETRM", "random pick (5×)"]);
    for i in 0..4 {
        t.row(vec![labels[i].to_string(), etrm[i].to_string(), random[i].to_string()]);
    }
    format!("Fig 8 — case count within distance from T_best ({} tasks)\n{}", eval.tasks.len(), t.render())
}

/// Table 7 — benefit (s) and benefit-cost ratio per (graph × algorithm).
pub fn table7(eval: &Evaluation) -> String {
    let mut header = vec!["graph".to_string()];
    header.extend(Algorithm::all().iter().map(|a| a.name().to_string()));
    let mut t = Table::new(header);
    for spec in crate::graph::datasets::CORPUS {
        let mut brow = vec![spec.name.to_string()];
        let mut crow = vec![format!("{} (BC)", spec.name)];
        for a in Algorithm::all() {
            match eval.tasks.iter().find(|x| x.graph == spec.name && x.algorithm == a) {
                Some(task) => {
                    brow.push(f(task.benefit, 3));
                    crow.push(f(task.bc_ratio(), 1));
                }
                None => {
                    brow.push("-".into());
                    crow.push("-".into());
                }
            }
        }
        t.row(brow);
        t.row(crow);
    }
    format!(
        "Table 7 — benefit (s, upper row) and benefit-cost ratio (lower row)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::pipeline::{run, PipelineConfig};

    fn tiny_eval() -> Evaluation {
        run(PipelineConfig {
            scale: 0.002,
            augment_cap: Some(2_000),
            r_hi: 3,
            gbdt: crate::ml::gbdt::GbdtParams {
                n_estimators: 40,
                max_depth: 5,
                ..crate::ml::gbdt::GbdtParams::fast()
            },
            ..PipelineConfig::fast_test()
        })
        .unwrap()
    }

    #[test]
    fn every_figure_renders() {
        let eval = tiny_eval();
        let outputs = [
            fig1(&eval),
            fig4(0.002, 42).unwrap(),
            table2(),
            table3(&eval).unwrap(),
            table4(&eval).unwrap(),
            fig6(&eval),
            fig7(&eval),
            table6(&eval),
            fig8(&eval),
            table7(&eval),
        ];
        for (i, o) in outputs.iter().enumerate() {
            assert!(o.lines().count() >= 4, "artifact {i} too small:\n{o}");
        }
        // structural checks
        assert!(outputs[0].contains("stanford/APCN"));
        assert!(outputs[2].contains("Ginger"));
        assert!(outputs[5].contains("≤11"));
        assert!(outputs[7].contains("All cases"));
        assert!(outputs[9].contains("road-ca (BC)"));
    }

    #[test]
    fn fig4_shows_scaling_shape() {
        // times strictly positive and the 64-worker PR beats 4-worker
        let s = fig4(0.01, 42).unwrap();
        let rows: Vec<Vec<f64>> = s
            .lines()
            .filter(|l| l.starts_with("| 4") || l.starts_with("| 6"))
            .map(|l| {
                l.split('|')
                    .filter_map(|c| c.trim().parse::<f64>().ok())
                    .collect()
            })
            .collect();
        assert!(rows.len() >= 2, "{s}");
        let (w4, w64) = (&rows[0], rows.last().unwrap());
        assert!(w64[1] < w4[1], "PR at 64 workers faster: {s}");
    }
}
