//! End-to-end experiment pipeline (Fig 2 of the paper, run as one shot):
//!
//! 1. build the execution-log corpus — every dataset × all 8 algorithms
//!    × the 11-strategy inventory, executed on the engine;
//! 2. augment the training-graph × training-algorithm logs into the
//!    synthetic set (§4.2.1);
//! 3. train the ETRM on the synthetic set only;
//! 4. evaluate the 96 test tasks (§5.4): select, rank, score, and
//!    measure the selection cost for the §5.7 benefit-cost ratio.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::algorithms::Algorithm;
use crate::analyzer::analyze;
use crate::dataset::augment::augment;
use crate::dataset::checkpoint;
use crate::dataset::logs::{ExecutionLog, LogStore};
use crate::dataset::split::{test_split, TestSet};
use crate::engine::cluster::ClusterSpec;
use crate::engine::ExecutionMode;
use crate::etrm::scores::{rank_of_selected, TaskScores};
use crate::etrm::Etrm;
use crate::features::{DataFeatures, TaskFeatures};
use crate::ml::gbdt::GbdtParams;
use crate::ml::Label;
use crate::partition::Strategy;
use crate::util::error::{ensure, Result};
use crate::util::pool;
use crate::util::rng::Rng;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Linear dataset scale (1.0 = the paper's sizes).
    pub scale: f64,
    /// Master seed.
    pub seed: u64,
    /// Cluster size (the paper: 64).
    pub workers: usize,
    /// Corpus-build worker threads; 0 = the `GPS_THREADS` env default
    /// (falling back to the machine's available parallelism). Results
    /// are bit-identical for any value.
    pub threads: usize,
    /// Engine backend the corpus tasks run on (default: the
    /// `GPS_ENGINE_MODE` env, falling back to `Simulated`). All three
    /// modes — simulated, threaded, socket — produce bit-identical
    /// deterministic log fields; only the measured `wall_clock_ms`
    /// channel differs run to run.
    pub engine_mode: ExecutionMode,
    /// Corpus checkpoint directory: finished graphs are committed as
    /// crash-safe shards and restored on the next run with the same
    /// configuration (default: the `GPS_CHECKPOINT_DIR` env, falling
    /// back to no checkpointing). Resumed builds are bit-identical to
    /// uninterrupted ones; a mismatched checkpoint is rejected.
    pub checkpoint_dir: Option<PathBuf>,
    /// Cap on synthetic tuples (None = the full ~0.43 M? at r 2..9 the
    /// full product is 4998 × 8 × 11 = 439 824).
    pub augment_cap: Option<usize>,
    /// Multiset size range for augmentation.
    pub r_lo: usize,
    pub r_hi: usize,
    /// ETRM hyper-parameters.
    pub gbdt: GbdtParams,
    /// Training-label channel: the simulated cost-model oracle
    /// (default) or the measured wall-clock column of the logs. The
    /// evaluation stage always *scores* selections against the
    /// simulated oracle — the deterministic, reproducible ground truth
    /// — whichever channel trained the model.
    pub label: Label,
    /// Cluster the corpus runs on. `None` (default) = the uniform
    /// paper cluster sized to `workers`; an explicit spec (its worker
    /// count must match `workers`) builds a skewed-cluster corpus whose
    /// logs carry the spec's cluster features, and is folded into the
    /// checkpoint manifest fingerprint.
    pub cluster: Option<ClusterSpec>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            scale: 1.0 / 32.0,
            seed: 42,
            workers: 64,
            threads: 0,
            engine_mode: ExecutionMode::from_env(),
            checkpoint_dir: checkpoint::resolve_dir(None),
            augment_cap: Some(120_000),
            r_lo: 2,
            r_hi: 9,
            gbdt: GbdtParams {
                n_estimators: 400,
                max_depth: 12,
                learning_rate: 0.08,
                ..GbdtParams::paper()
            },
            label: Label::SimTime,
            cluster: None,
        }
    }
}

impl PipelineConfig {
    /// A fast profile for tests: tiny graphs, light model. Pins
    /// `checkpoint_dir` to `None` (unlike `Default`, which honours
    /// `GPS_CHECKPOINT_DIR`) so a developer's exported env var cannot
    /// make differently-configured test pipelines collide in — or
    /// silently reuse — one checkpoint directory.
    pub fn fast_test() -> Self {
        PipelineConfig {
            scale: 0.004,
            workers: 16,
            checkpoint_dir: None,
            augment_cap: Some(6_000),
            r_hi: 5,
            gbdt: GbdtParams { n_estimators: 120, max_depth: 8, ..GbdtParams::fast() },
            ..Default::default()
        }
    }
}

/// Per-task evaluation record.
#[derive(Clone, Debug)]
pub struct TaskEval {
    pub graph: String,
    pub algorithm: Algorithm,
    pub set: TestSet,
    /// ETRM's pick.
    pub selected: Strategy,
    /// 1-based actual rank of the pick among the 11 strategies.
    pub rank: usize,
    /// Eq. 19-21 scores.
    pub scores: TaskScores,
    /// Real times per strategy (inventory order).
    pub times: Vec<(Strategy, f64)>,
    /// The pick's real time.
    pub t_sel: f64,
    /// Selection cost components (measured wall seconds): data-feature
    /// extraction (measured once per graph and amortised evenly over
    /// that graph's test tasks — the features are computed once and
    /// reused, so no task is charged the full extraction again), code
    /// analysis, model predict.
    pub cost_data: f64,
    pub cost_algo: f64,
    pub cost_predict: f64,
    /// §5.7 benefit: `T_worst − T_sel` (simulated seconds).
    pub benefit: f64,
}

impl TaskEval {
    /// §5.7 benefit-cost ratio.
    pub fn bc_ratio(&self) -> f64 {
        self.benefit / (self.cost_data + self.cost_algo + self.cost_predict).max(1e-12)
    }
}

/// Full pipeline output.
pub struct Evaluation {
    pub config: PipelineConfig,
    /// The real-execution corpus (1056 logs at full corpus).
    pub store: LogStore,
    /// Number of synthetic training tuples used.
    pub synthetic_count: usize,
    /// The trained model.
    pub etrm: Etrm,
    /// The 96-task evaluation.
    pub tasks: Vec<TaskEval>,
}

/// Stages 1-2 output: the real-execution corpus plus the synthetic
/// augmented training set, before any model is trained. `repro train`
/// consumes this directly so it can pick its own backend.
pub struct TrainingSet {
    pub store: LogStore,
    pub synthetic: Vec<ExecutionLog>,
}

/// Stages 1-3 output: the train-once half of the lifecycle. `repro
/// train` persists `etrm` via [`crate::etrm::store::save`];
/// [`run_with_progress`] continues into the 96-task evaluation.
pub struct TrainedModel {
    pub store: LogStore,
    pub synthetic: Vec<ExecutionLog>,
    pub etrm: Etrm,
}

/// Stages 1-2: build (or resume) the execution-log corpus and augment
/// the synthetic training set.
pub fn build_training_set(
    config: &PipelineConfig,
    progress: &mut impl FnMut(&str),
) -> Result<TrainingSet> {
    let cfg =
        config.cluster.clone().unwrap_or_else(|| ClusterSpec::with_workers(config.workers));
    ensure!(
        cfg.num_workers() == config.workers,
        "pipeline cluster spec has {} workers, but config.workers is {}",
        cfg.num_workers(),
        config.workers
    );
    let threads = pool::resolve_threads(config.threads);
    progress(&format!(
        "building execution-log corpus (12 graphs × 8 algorithms × 11 strategies, \
         {threads} threads, {} engine)",
        config.engine_mode.name()
    ));
    if let Some(dir) = config.checkpoint_dir.as_deref() {
        progress(&format!(
            "corpus checkpointing to {} (finished graphs are restored on resume)",
            dir.display()
        ));
    }
    let store = LogStore::build_corpus_checkpointed(
        config.scale,
        config.seed,
        &cfg,
        threads,
        config.engine_mode,
        config.checkpoint_dir.as_deref(),
    )?;
    progress("augmenting synthetic training set");
    let synthetic = augment(&store, config.r_lo..=config.r_hi, config.augment_cap, config.seed);
    Ok(TrainingSet { store, synthetic })
}

/// Stages 1-3: build the training set and train the GBDT ETRM on the
/// configured label channel — the shared train-once front half of
/// [`run_with_progress`] and `repro train --model-out`.
pub fn train_with_progress(
    config: &PipelineConfig,
    progress: &mut impl FnMut(&str),
) -> Result<TrainedModel> {
    let TrainingSet { store, synthetic } = build_training_set(config, progress)?;
    progress(&format!("training ETRM (histogram GBDT, {} label)", config.label.name()));
    let etrm = Etrm::train_gbdt(&synthetic, config.gbdt, config.label);
    Ok(TrainedModel { store, synthetic, etrm })
}

/// Run the full pipeline.
pub fn run(config: PipelineConfig) -> Result<Evaluation> {
    run_with_progress(config, |_| {})
}

/// Run with a progress callback (the CLI prints stage banners). The
/// evaluation stage always ranks and scores against the simulated
/// oracle times — the reproducible ground truth — regardless of which
/// label channel trained the model.
#[allow(clippy::disallowed_methods)] // §5.7 cost timings below, not execution labels
pub fn run_with_progress(
    config: PipelineConfig,
    mut progress: impl FnMut(&str),
) -> Result<Evaluation> {
    let TrainedModel { store, synthetic, etrm } = train_with_progress(&config, &mut progress)?;
    let synthetic_count = synthetic.len();

    progress("evaluating 96 test tasks");
    let split = test_split();
    // Each distinct graph is built once and its data features are
    // extracted exactly once, shared by all of the graph's test tasks.
    // The measured extraction time (the §5.7 "cost") is amortised
    // evenly over those tasks: the selector pays for the sweep once per
    // graph, so charging every task the full cost — let alone
    // re-running the extraction per task, as this loop used to — would
    // overstate the §5.7 cost eightfold.
    let mut tasks_per_graph: BTreeMap<&'static str, f64> = BTreeMap::new();
    for t in &split {
        *tasks_per_graph.entry(t.graph).or_insert(0.0) += 1.0;
    }
    // Evaluation tasks carry the same cluster features the corpus logs
    // were built with, so the model sees a consistent feature space.
    let cluster_feats = config
        .cluster
        .as_ref()
        .map_or_else(|| ClusterSpec::with_workers(config.workers).features(), |c| c.features());
    let mut features_of: BTreeMap<&'static str, (DataFeatures, f64)> = BTreeMap::new();
    let mut tasks = Vec::with_capacity(split.len());
    for t in split {
        let (data, graph_cost) = *features_of.entry(t.graph).or_insert_with(|| {
            let spec = crate::graph::datasets::DatasetSpec::by_name(t.graph).unwrap();
            let g = spec.build(config.scale, config.seed);
            // audit:allow(instant-now): §5.7 feature-extraction cost, reported only
            let t0 = Instant::now();
            let data = DataFeatures::of(&g);
            (data, t0.elapsed().as_secs_f64())
        });
        let cost_data = graph_cost / tasks_per_graph[t.graph];
        // audit:allow(instant-now): §5.7 analyzer cost, reported only
        let t0 = Instant::now();
        let counts = analyze(t.algorithm.pseudo_code())?;
        let cost_algo = t0.elapsed().as_secs_f64();
        let mut features = TaskFeatures::from_parts(data, &counts);
        features.cluster = cluster_feats;
        // audit:allow(instant-now): §5.7 prediction cost, reported only
        let t0 = Instant::now();
        let selected = etrm.select(&features);
        let cost_predict = t0.elapsed().as_secs_f64();

        let times: Vec<(Strategy, f64)> = Strategy::inventory()
            .into_iter()
            .map(|s| {
                let time = store
                    .time_of(t.graph, t.algorithm.name(), s)
                    .expect("corpus covers all test tasks");
                (s, time)
            })
            .collect();
        let t_sel = times.iter().find(|(s, _)| *s == selected).unwrap().1;
        let raw: Vec<f64> = times.iter().map(|(_, x)| *x).collect();
        let worst = raw.iter().cloned().fold(0.0, f64::max);
        tasks.push(TaskEval {
            graph: t.graph.to_string(),
            algorithm: t.algorithm,
            set: t.set,
            selected,
            rank: rank_of_selected(&times, selected),
            scores: TaskScores::compute(&raw, t_sel),
            times,
            t_sel,
            cost_data,
            cost_algo,
            cost_predict,
            benefit: worst - t_sel,
        });
    }
    Ok(Evaluation { config, store, synthetic_count, etrm, tasks })
}

impl Evaluation {
    /// Tasks of one test set.
    pub fn of_set(&self, set: TestSet) -> Vec<&TaskEval> {
        self.tasks.iter().filter(|t| t.set == set).collect()
    }

    /// Cumulative rank ratio curve (Fig 6): entry `r-1` = fraction of
    /// `tasks` with actual rank ≤ r.
    pub fn cumulative_rank_ratio(tasks: &[&TaskEval]) -> Vec<f64> {
        let n = tasks.len().max(1) as f64;
        (1..=Strategy::inventory().len())
            .map(|r| tasks.iter().filter(|t| t.rank <= r).count() as f64 / n)
            .collect()
    }

    /// Mean Eq. 19-21 scores over a task subset (Table 6 rows).
    pub fn mean_scores(tasks: &[&TaskEval]) -> (f64, f64, f64) {
        let n = tasks.len().max(1) as f64;
        let sum = tasks.iter().fold((0.0, 0.0, 0.0), |acc, t| {
            (acc.0 + t.scores.best, acc.1 + t.scores.worst, acc.2 + t.scores.avg)
        });
        (sum.0 / n, sum.1 / n, sum.2 / n)
    }

    /// The random-pick baseline of Fig 8: mean `Score_best` of 5 random
    /// strategies per task (seeded).
    pub fn random_baseline_scores(&self, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let inv = Strategy::inventory();
        self.tasks
            .iter()
            .map(|t| {
                let best = t.times.iter().map(|(_, x)| *x).fold(f64::INFINITY, f64::min);
                let mean_perf: f64 = (0..5)
                    .map(|_| {
                        let s = inv[rng.gen_range(inv.len())];
                        let time = t.times.iter().find(|(x, _)| *x == s).unwrap().1;
                        best / time
                    })
                    .sum::<f64>()
                    / 5.0;
                mean_perf
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The whole pipeline at test scale: structure + the paper's
    /// qualitative claims (ETRM beats random, Score_worst > 1, …).
    #[test]
    fn pipeline_end_to_end_fast() {
        let eval = run(PipelineConfig::fast_test()).unwrap();
        assert_eq!(eval.tasks.len(), 96);
        assert_eq!(eval.store.logs.len(), 12 * 8 * 11);
        assert_eq!(eval.etrm.label, crate::ml::Label::SimTime, "default channel is the oracle");
        assert!(eval.synthetic_count > 1000, "{}", eval.synthetic_count);
        // per-set cardinalities
        assert_eq!(eval.of_set(TestSet::A).len(), 8);
        assert_eq!(eval.of_set(TestSet::B).len(), 24);
        assert_eq!(eval.of_set(TestSet::C).len(), 16);
        assert_eq!(eval.of_set(TestSet::D).len(), 48);
        // every rank in range, curve monotone to 1.0
        assert!(eval.tasks.iter().all(|t| (1..=11).contains(&t.rank)));
        let all: Vec<&TaskEval> = eval.tasks.iter().collect();
        let curve = Evaluation::cumulative_rank_ratio(&all);
        assert_eq!(curve.len(), 11);
        assert!(curve.windows(2).all(|w| w[0] <= w[1] + 1e-12));
        assert!((curve[10] - 1.0).abs() < 1e-12);
        // headline shape: the selector beats the random baseline and
        // the mean strategy on average
        let (best, worst, avg) = Evaluation::mean_scores(&all);
        assert!(best > 0.5, "Score_best {best}");
        assert!(worst >= 1.0, "Score_worst {worst}");
        assert!(avg > 0.9, "Score_avg {avg}");
        let rnd = eval.random_baseline_scores(7);
        let rnd_mean: f64 = rnd.iter().sum::<f64>() / rnd.len() as f64;
        assert!(
            best > rnd_mean,
            "ETRM Score_best {best} must beat random {rnd_mean}"
        );
        // benefit/cost well-defined
        assert!(eval.tasks.iter().all(|t| t.benefit >= 0.0 && t.bc_ratio() >= 0.0));
        // §5.7 cost accounting: data features are extracted once per
        // graph and amortised evenly, so every task of a graph carries
        // the identical (bit-equal) cost_data share
        let mut share: std::collections::BTreeMap<&str, f64> = Default::default();
        for t in &eval.tasks {
            let s = share.entry(t.graph.as_str()).or_insert(t.cost_data);
            assert_eq!(
                s.to_bits(),
                t.cost_data.to_bits(),
                "cost_data differs between tasks of {}",
                t.graph
            );
        }
    }
}
