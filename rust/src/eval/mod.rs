//! Evaluation harness: regenerates every table and figure of §5.
//!
//! [`pipeline`] runs the end-to-end experiment (corpus → augmentation →
//! ETRM training → 96-task evaluation); [`figures`] renders each paper
//! artifact from the result. The `repro figures --id <fig1|fig4|…|all>`
//! CLI and the `cargo bench` targets both route through here.

pub mod figures;
pub mod pipeline;

pub use pipeline::{Evaluation, PipelineConfig, TaskEval};
