//! Data features (Table 3): cardinalities, in/out degree-distribution
//! moments and graph direction.

use crate::analyzer::symbolic::SymEnv;
use crate::graph::stats::DegreeStats;
use crate::graph::Graph;
use crate::util::stats::Moments;

/// The four moments of one degree distribution, in feature form.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MomentFeatures {
    pub mean: f64,
    pub std: f64,
    pub skewness: f64,
    pub kurtosis: f64,
}

impl From<Moments> for MomentFeatures {
    fn from(m: Moments) -> Self {
        MomentFeatures { mean: m.mean, std: m.std, skewness: m.skewness, kurtosis: m.kurtosis }
    }
}

/// Table 3 data features of one graph.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DataFeatures {
    pub num_vertices: f64,
    pub num_edges: f64,
    pub directed: bool,
    pub in_deg: MomentFeatures,
    pub out_deg: MomentFeatures,
}

impl DataFeatures {
    /// Extract from a graph (pure-Rust moments path).
    pub fn of(g: &Graph) -> Self {
        Self::from_stats(&DegreeStats::of(g))
    }

    /// Assemble from pre-computed degree statistics (the PJRT `moments`
    /// kernel path produces the same [`DegreeStats`]).
    pub fn from_stats(s: &DegreeStats) -> Self {
        DataFeatures {
            num_vertices: s.num_vertices as f64,
            num_edges: s.num_edges as f64,
            directed: s.directed,
            in_deg: s.in_deg.into(),
            out_deg: s.out_deg.into(),
        }
    }

    /// Symbol environment for evaluating the analyzer's symbolic counts
    /// against this graph.
    pub fn sym_env(&self) -> SymEnv {
        let mean_both = if self.directed {
            self.in_deg.mean + self.out_deg.mean
        } else {
            self.out_deg.mean
        };
        SymEnv {
            num_vertex: self.num_vertices,
            num_edge: self.num_edges,
            mean_in_deg: self.in_deg.mean,
            mean_out_deg: self.out_deg.mean,
            mean_both_deg: mean_both,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extraction_matches_stats() {
        let mut rng = crate::util::rng::Rng::new(400);
        let g = crate::graph::gen::chung_lu::generate("t", 500, 3000, 2.2, true, &mut rng);
        let f = DataFeatures::of(&g);
        assert_eq!(f.num_vertices, 500.0);
        assert_eq!(f.num_edges, 3000.0);
        assert!(f.directed);
        assert!((f.out_deg.mean - 6.0).abs() < 1e-9, "mean out = |E|/|V|");
        assert!(f.out_deg.kurtosis > 0.0, "power-law tail");
    }

    #[test]
    fn sym_env_direction_convention() {
        let gd = crate::graph::Graph::from_edges("d", 3, vec![(0, 1), (1, 2)], true);
        let fd = DataFeatures::of(&gd);
        let env = fd.sym_env();
        assert!((env.mean_both_deg - (env.mean_in_deg + env.mean_out_deg)).abs() < 1e-12);
        let gu = crate::graph::Graph::from_edges("u", 3, vec![(0, 1), (1, 2)], false);
        let fu = DataFeatures::of(&gu);
        let envu = fu.sym_env();
        assert!((envu.mean_both_deg - envu.mean_out_deg).abs() < 1e-12);
    }
}
