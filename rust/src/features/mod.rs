//! Task feature extraction and model-input encoding (§4.1, Fig 5).
//!
//! A *task feature* is the concatenation of the graph's data features
//! (Table 3) and the algorithm's evaluated operation counts (Table 4);
//! the ETRM input appends a one-hot partitioning-strategy id
//! (Fig 5) and scales magnitudes with `log1p` (counts span 9+ orders
//! of magnitude between AID on facebook and APCN on stanford).

pub mod data;
pub mod encoding;
pub mod task;

pub use data::DataFeatures;
pub use encoding::{
    encode, encode_into, feature_names, task_from_values, task_to_values, zeroed_task,
    FEATURE_DIM, TASK_WIRE_DIM,
};
pub use task::TaskFeatures;
