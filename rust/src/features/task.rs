//! Task features: data features ⊕ algorithm features (Fig 2 steps 1-2).

use crate::analyzer::{analyze, AlgoCounts, NUM_OP_KEYS};
use crate::engine::cluster::ClusterFeatures;
use crate::graph::Graph;
use crate::util::error::Result;

use super::data::DataFeatures;

/// The feature bundle of one task (graph × algorithm × cluster).
#[derive(Clone, Debug)]
pub struct TaskFeatures {
    /// Table 3 features of the graph.
    pub data: DataFeatures,
    /// Evaluated Table 4 counts ([`NUM_OP_KEYS`] entries, Table 4
    /// order).
    pub algo: [f64; NUM_OP_KEYS],
    /// Cluster-feature block of the cluster the task targets
    /// (heterogeneity summary: speed spread, link-tier spread). The
    /// default is the uniform paper cluster, which every constructor
    /// stamps; callers running against a non-default
    /// [`crate::engine::cluster::ClusterSpec`] overwrite it with
    /// `spec.features()`.
    pub cluster: ClusterFeatures,
}

impl TaskFeatures {
    /// Extract from a graph and pseudo-code source. The extraction
    /// itself is what the paper's "cost" measures (§5.7): graph-feature
    /// time scales with |V|+|E|, code analysis is constant-ish.
    pub fn extract(g: &Graph, pseudo_code: &str) -> Result<Self> {
        let data = DataFeatures::of(g);
        let counts = analyze(pseudo_code)?;
        Ok(Self::from_parts(data, &counts))
    }

    /// Assemble from already-computed parts (synthetic-augmentation and
    /// PJRT paths).
    pub fn from_parts(data: DataFeatures, counts: &AlgoCounts) -> Self {
        let algo = counts.feature_vector(&data.sym_env());
        TaskFeatures { data, algo, cluster: ClusterFeatures::default() }
    }

    /// Assemble from a raw evaluated algorithm-feature vector.
    pub fn from_vector(data: DataFeatures, algo: [f64; NUM_OP_KEYS]) -> Self {
        TaskFeatures { data, algo, cluster: ClusterFeatures::default() }
    }

    /// Sum of algorithm features — the aggregation used when synthetic
    /// tasks are built from sequences of real algorithms (§4.2.1:
    /// `AF(s) = Σ AF(r_i)`). The cluster block is *not* summed: a
    /// synthetic task targets the same cluster as its members, so the
    /// caller stamps it (the default is the uniform paper cluster).
    pub fn aggregate_algos(data: DataFeatures, parts: &[[f64; NUM_OP_KEYS]]) -> Self {
        let mut algo = [0.0; NUM_OP_KEYS];
        for p in parts {
            for i in 0..NUM_OP_KEYS {
                algo[i] += p[i];
            }
        }
        TaskFeatures { data, algo, cluster: ClusterFeatures::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Algorithm;

    #[test]
    fn extract_pr_features() {
        let mut rng = crate::util::rng::Rng::new(410);
        let g = crate::graph::gen::erdos::generate("t", 200, 1000, true, &mut rng);
        let tf = TaskFeatures::extract(&g, Algorithm::Pr.pseudo_code()).unwrap();
        assert_eq!(tf.data.num_vertices, 200.0);
        // PR applies once per vertex per iteration (10)
        let apply_idx = crate::analyzer::OpKey::all()
            .iter()
            .position(|k| *k == crate::analyzer::OpKey::Apply)
            .unwrap();
        assert_eq!(tf.algo[apply_idx], 2000.0);
    }

    #[test]
    fn aggregation_is_summation() {
        let mut rng = crate::util::rng::Rng::new(411);
        let g = crate::graph::gen::erdos::generate("t", 100, 400, true, &mut rng);
        let a = TaskFeatures::extract(&g, Algorithm::Aid.pseudo_code()).unwrap();
        let b = TaskFeatures::extract(&g, Algorithm::Pr.pseudo_code()).unwrap();
        let s = TaskFeatures::aggregate_algos(a.data, &[a.algo, b.algo, b.algo]);
        for i in 0..NUM_OP_KEYS {
            assert!((s.algo[i] - (a.algo[i] + 2.0 * b.algo[i])).abs() < 1e-9);
        }
    }
}
