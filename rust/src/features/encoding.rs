//! Model-input encoding (Fig 5): scaling + one-hot.
//!
//! Layout (52 columns):
//!
//! | cols  | content |
//! |-------|---------|
//! | 0-1   | log1p(|V|), log1p(|E|) |
//! | 2-9   | in-degree: log1p(mean), log1p(std), sign(skew), log1p(|skew|), sign(kurt), log1p(|kurt|) is 6 → cols 2-7; see below |
//! | 2-7   | in-degree moments (mean, std, skew sign/abs, kurt sign/abs) |
//! | 8-13  | out-degree moments (same shape) |
//! | 14-15 | direction one-hot (undirected, directed) |
//! | 16-36 | 21 algorithm features, log1p |
//! | 37-47 | strategy one-hot (PSID order of `Strategy::inventory()`, 11) |
//! | 48-51 | strategy family flags (hash, greedy, degree-aware, grid) |
//!
//! Skewness/kurtosis are split into sign and magnitude exactly as
//! §4.1.1 describes ("divided into a sign and absolute value").

use crate::analyzer::OpKey;
use crate::partition::Strategy;

use super::data::MomentFeatures;
use super::task::TaskFeatures;

/// Total encoded width.
pub const FEATURE_DIM: usize = 52;

fn log1p(x: f64) -> f64 {
    (1.0 + x.max(0.0)).ln()
}

fn push_moments(push: &mut impl FnMut(f64), m: &MomentFeatures) {
    push(log1p(m.mean));
    push(log1p(m.std));
    push(if m.skewness < 0.0 { -1.0 } else { 1.0 });
    push(log1p(m.skewness.abs()));
    push(if m.kurtosis < 0.0 { -1.0 } else { 1.0 });
    push(log1p(m.kurtosis.abs()));
}

/// Encode one (task, strategy) pair into a caller-provided buffer —
/// the allocation-free hot path of prediction: batched selection
/// encodes all 11 candidate strategies of a task into one reused stack
/// buffer instead of allocating a vector per predict.
pub fn encode_into(task: &TaskFeatures, strategy: Strategy, out: &mut [f64; FEATURE_DIM]) {
    let mut i = 0usize;
    let mut push = |v: f64| {
        out[i] = v;
        i += 1;
    };
    push(log1p(task.data.num_vertices));
    push(log1p(task.data.num_edges));
    push_moments(&mut push, &task.data.in_deg);
    push_moments(&mut push, &task.data.out_deg);
    // direction one-hot
    push(if task.data.directed { 0.0 } else { 1.0 });
    push(if task.data.directed { 1.0 } else { 0.0 });
    // 21 algorithm counts
    for &x in &task.algo {
        push(log1p(x));
    }
    // strategy one-hot over the 11-strategy inventory
    for s in Strategy::INVENTORY {
        push(if s == strategy { 1.0 } else { 0.0 });
    }
    // family flags help the tree generalise across related strategies
    let (hash, greedy, degree_aware, grid) = match strategy {
        Strategy::OneDSrc | Strategy::OneDDst | Strategy::Random | Strategy::CanonicalRandom => {
            (1.0, 0.0, 0.0, 0.0)
        }
        Strategy::TwoD => (1.0, 0.0, 0.0, 1.0),
        Strategy::Hybrid => (1.0, 0.0, 1.0, 0.0),
        Strategy::Oblivious => (0.0, 1.0, 0.0, 0.0),
        Strategy::Hdrf(_) => (0.0, 1.0, 1.0, 0.0),
        Strategy::Ginger => (0.0, 1.0, 1.0, 0.0),
    };
    push(hash);
    push(greedy);
    push(degree_aware);
    push(grid);
    debug_assert_eq!(i, FEATURE_DIM);
}

/// Encode one (task, strategy) pair into the model-input vector.
pub fn encode(task: &TaskFeatures, strategy: Strategy) -> [f64; FEATURE_DIM] {
    let mut out = [0.0; FEATURE_DIM];
    encode_into(task, strategy, &mut out);
    out
}

/// Column names (for importance reporting, Tables 3/4).
pub fn feature_names() -> Vec<String> {
    let mut names = vec!["num_vertex".to_string(), "num_edge".to_string()];
    for dir in ["in", "out"] {
        for m in ["mean", "std", "skew_sign", "skew_abs", "kurt_sign", "kurt_abs"] {
            names.push(format!("{dir}_deg_{m}"));
        }
    }
    names.push("undirected".into());
    names.push("directed".into());
    for k in OpKey::all() {
        names.push(k.name().to_lowercase());
    }
    for s in Strategy::inventory() {
        names.push(format!("strategy_{}", s.name().to_lowercase()));
    }
    names.extend(
        ["family_hash", "family_greedy", "family_degree_aware", "family_grid"]
            .map(String::from),
    );
    assert_eq!(names.len(), FEATURE_DIM);
    names
}

/// Which Table-3 row an encoded column belongs to, if any (used to
/// aggregate per-column importance into the paper's data-feature rows).
pub fn table3_group(col: usize) -> Option<&'static str> {
    match col {
        0 => Some("The number of Vertex"),
        1 => Some("The number of Edge"),
        2..=7 => Some("In-degree"),
        8..=13 => Some("Out-degree"),
        14 | 15 => Some("Graph direction"),
        _ => None,
    }
}

/// Which Table-4 row an encoded column belongs to, if any.
pub fn table4_group(col: usize) -> Option<&'static str> {
    if (16..37).contains(&col) {
        Some(OpKey::all()[col - 16].name())
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::data::DataFeatures;

    fn task() -> TaskFeatures {
        let mut rng = crate::util::rng::Rng::new(420);
        let g = crate::graph::gen::chung_lu::generate("t", 300, 2000, 2.2, true, &mut rng);
        let data = DataFeatures::of(&g);
        TaskFeatures::from_vector(data, [10.0; crate::analyzer::NUM_OP_KEYS])
    }

    #[test]
    fn dimension_and_names_agree() {
        let t = task();
        let v = encode(&t, Strategy::Hybrid);
        assert_eq!(v.len(), FEATURE_DIM);
        assert_eq!(feature_names().len(), FEATURE_DIM);
    }

    /// The buffer-reuse path is the same encoding: writing two
    /// different strategies into one buffer leaves exactly the second
    /// strategy's vector (every slot is overwritten, none is stale).
    #[test]
    fn encode_into_reused_buffer_matches_encode() {
        let t = task();
        let mut buf = [0.0; FEATURE_DIM];
        encode_into(&t, Strategy::Ginger, &mut buf);
        assert_eq!(buf, encode(&t, Strategy::Ginger));
        encode_into(&t, Strategy::OneDSrc, &mut buf);
        assert_eq!(buf, encode(&t, Strategy::OneDSrc));
    }

    #[test]
    fn strategy_onehot_position() {
        let t = task();
        let names = feature_names();
        for (i, s) in Strategy::inventory().into_iter().enumerate() {
            let v = encode(&t, s);
            let hot: Vec<usize> =
                (37..48).filter(|&c| v[c] == 1.0).collect();
            assert_eq!(hot, vec![37 + i], "{}", s.name());
            assert_eq!(names[37 + i], format!("strategy_{}", s.name().to_lowercase()));
        }
    }

    #[test]
    fn sign_split_encoding() {
        let mut t = task();
        t.data.in_deg.skewness = -2.0;
        let v = encode(&t, Strategy::Random);
        assert_eq!(v[4], -1.0, "skew sign column");
        assert!((v[5] - (3.0f64).ln()).abs() < 1e-12, "log1p(|skew|)");
    }

    #[test]
    fn direction_onehot() {
        let mut t = task();
        t.data.directed = false;
        let v = encode(&t, Strategy::Random);
        assert_eq!((v[14], v[15]), (1.0, 0.0));
        t.data.directed = true;
        let v = encode(&t, Strategy::Random);
        assert_eq!((v[14], v[15]), (0.0, 1.0));
    }

    #[test]
    fn group_mappings_cover_tables() {
        assert_eq!(table3_group(0), Some("The number of Vertex"));
        assert_eq!(table3_group(9), Some("Out-degree"));
        assert_eq!(table3_group(16), None);
        assert_eq!(table4_group(16), Some("NUM_VERTEX"));
        assert_eq!(table4_group(36), Some("APPLY"));
        assert_eq!(table4_group(37), None);
    }

    #[test]
    fn hdrf_variants_share_family_but_not_onehot() {
        let t = task();
        let a = encode(&t, Strategy::Hdrf(10));
        let b = encode(&t, Strategy::Hdrf(100));
        assert_ne!(a[37..48], b[37..48]);
        assert_eq!(a[48..], b[48..]);
    }
}
