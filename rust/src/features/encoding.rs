//! Model-input encoding (Fig 5): scaling + one-hot.
//!
//! Layout (59 columns):
//!
//! | cols  | content |
//! |-------|---------|
//! | 0-1   | log1p(|V|), log1p(|E|) |
//! | 2-7   | in-degree moments (mean, std, skew sign/abs, kurt sign/abs) |
//! | 8-13  | out-degree moments (same shape) |
//! | 14-15 | direction one-hot (undirected, directed) |
//! | 16-36 | 21 algorithm features, log1p |
//! | 37-47 | strategy one-hot (PSID order of `Strategy::inventory()`, 11) |
//! | 48-51 | strategy family flags (hash, greedy, degree-aware, grid) |
//! | 52-58 | cluster block ([`crate::engine::cluster::ClusterFeatures`]) |
//!
//! Skewness/kurtosis are split into sign and magnitude exactly as
//! §4.1.1 describes ("divided into a sign and absolute value"). The
//! cluster block is appended *after* every paper column so the pinned
//! Table-3/Table-4/one-hot column indices are unchanged.

use crate::analyzer::{OpKey, NUM_OP_KEYS};
use crate::engine::cluster::CLUSTER_FEATURE_DIM;
use crate::partition::Strategy;

use super::data::{DataFeatures, MomentFeatures};
use super::task::TaskFeatures;

/// Total encoded width.
pub const FEATURE_DIM: usize = 52 + CLUSTER_FEATURE_DIM;

/// Width of the raw task-transport image used by the selection
/// service's wire protocol: the un-scaled [`TaskFeatures`] fields in a
/// fixed order (|V|, |E|, directed flag, 2×4 degree moments,
/// [`NUM_OP_KEYS`] algorithm counts). Unlike the model input
/// ([`FEATURE_DIM`]), nothing here is log-scaled or one-hot — the
/// receiver re-encodes through [`encode_into`], so both sides of the
/// wire run the identical encoding path and selections stay
/// bit-identical to a local `select`.
pub const TASK_WIRE_DIM: usize = 11 + NUM_OP_KEYS;

/// Flatten a task into its transport image (the inverse of
/// [`task_from_values`]). Raw `f64` copies only — the values cross the
/// wire as exact bit patterns.
pub fn task_to_values(task: &TaskFeatures, out: &mut [f64; TASK_WIRE_DIM]) {
    out[0] = task.data.num_vertices;
    out[1] = task.data.num_edges;
    out[2] = if task.data.directed { 1.0 } else { 0.0 };
    for (base, m) in [(3usize, &task.data.in_deg), (7, &task.data.out_deg)] {
        out[base] = m.mean;
        out[base + 1] = m.std;
        out[base + 2] = m.skewness;
        out[base + 3] = m.kurtosis;
    }
    out[11..].copy_from_slice(&task.algo);
}

/// Rebuild a task from its transport image, writing into a reused
/// `TaskFeatures` (the service decodes every request into
/// per-connection buffers instead of allocating per task).
pub fn task_from_values(vals: &[f64; TASK_WIRE_DIM], into: &mut TaskFeatures) {
    into.data.num_vertices = vals[0];
    into.data.num_edges = vals[1];
    into.data.directed = vals[2] != 0.0;
    into.data.in_deg =
        MomentFeatures { mean: vals[3], std: vals[4], skewness: vals[5], kurtosis: vals[6] };
    into.data.out_deg =
        MomentFeatures { mean: vals[7], std: vals[8], skewness: vals[9], kurtosis: vals[10] };
    into.algo.copy_from_slice(&vals[11..]);
}

/// An all-zero task — the reusable decode target [`task_from_values`]
/// overwrites field-for-field.
pub fn zeroed_task() -> TaskFeatures {
    let zero = MomentFeatures { mean: 0.0, std: 0.0, skewness: 0.0, kurtosis: 0.0 };
    let data = DataFeatures {
        num_vertices: 0.0,
        num_edges: 0.0,
        directed: false,
        in_deg: zero,
        out_deg: zero,
    };
    TaskFeatures::from_vector(data, [0.0; NUM_OP_KEYS])
}

fn log1p(x: f64) -> f64 {
    (1.0 + x.max(0.0)).ln()
}

fn push_moments(push: &mut impl FnMut(f64), m: &MomentFeatures) {
    push(log1p(m.mean));
    push(log1p(m.std));
    push(if m.skewness < 0.0 { -1.0 } else { 1.0 });
    push(log1p(m.skewness.abs()));
    push(if m.kurtosis < 0.0 { -1.0 } else { 1.0 });
    push(log1p(m.kurtosis.abs()));
}

/// Encode one (task, strategy) pair into a caller-provided buffer —
/// the allocation-free hot path of prediction: batched selection
/// encodes all 11 candidate strategies of a task into one reused stack
/// buffer instead of allocating a vector per predict.
pub fn encode_into(task: &TaskFeatures, strategy: Strategy, out: &mut [f64; FEATURE_DIM]) {
    let mut i = 0usize;
    let mut push = |v: f64| {
        out[i] = v;
        i += 1;
    };
    push(log1p(task.data.num_vertices));
    push(log1p(task.data.num_edges));
    push_moments(&mut push, &task.data.in_deg);
    push_moments(&mut push, &task.data.out_deg);
    // direction one-hot
    push(if task.data.directed { 0.0 } else { 1.0 });
    push(if task.data.directed { 1.0 } else { 0.0 });
    // 21 algorithm counts
    for &x in &task.algo {
        push(log1p(x));
    }
    // strategy one-hot over the 11-strategy inventory
    for s in Strategy::INVENTORY {
        push(if s == strategy { 1.0 } else { 0.0 });
    }
    // family flags help the tree generalise across related strategies
    let (hash, greedy, degree_aware, grid) = match strategy {
        Strategy::OneDSrc | Strategy::OneDDst | Strategy::Random | Strategy::CanonicalRandom => {
            (1.0, 0.0, 0.0, 0.0)
        }
        Strategy::TwoD => (1.0, 0.0, 0.0, 1.0),
        Strategy::Hybrid => (1.0, 0.0, 1.0, 0.0),
        Strategy::Oblivious => (0.0, 1.0, 0.0, 0.0),
        Strategy::Hdrf(_) => (0.0, 1.0, 1.0, 0.0),
        Strategy::Ginger => (0.0, 1.0, 1.0, 0.0),
    };
    push(hash);
    push(greedy);
    push(degree_aware);
    push(grid);
    // cluster block: speed spread (scaled like the other magnitudes),
    // link spread, tier count — lets one model condition its choice on
    // which cluster the task will run on
    let c = &task.cluster;
    push(log1p(c.speed_min));
    push(log1p(c.speed_max));
    push(c.speed_cv);
    push(log1p(c.bw_min));
    push(log1p(c.bw_max));
    push(log1p(c.latency_max * 1e6));
    push(c.tier_count);
    debug_assert_eq!(i, FEATURE_DIM);
}

/// Encode one (task, strategy) pair into the model-input vector.
pub fn encode(task: &TaskFeatures, strategy: Strategy) -> [f64; FEATURE_DIM] {
    let mut out = [0.0; FEATURE_DIM];
    encode_into(task, strategy, &mut out);
    out
}

/// Column names (for importance reporting, Tables 3/4).
pub fn feature_names() -> Vec<String> {
    let mut names = vec!["num_vertex".to_string(), "num_edge".to_string()];
    for dir in ["in", "out"] {
        for m in ["mean", "std", "skew_sign", "skew_abs", "kurt_sign", "kurt_abs"] {
            names.push(format!("{dir}_deg_{m}"));
        }
    }
    names.push("undirected".into());
    names.push("directed".into());
    for k in OpKey::all() {
        names.push(k.name().to_lowercase());
    }
    for s in Strategy::inventory() {
        names.push(format!("strategy_{}", s.name().to_lowercase()));
    }
    names.extend(
        ["family_hash", "family_greedy", "family_degree_aware", "family_grid"]
            .map(String::from),
    );
    names.extend(
        [
            "cluster_speed_min",
            "cluster_speed_max",
            "cluster_speed_cv",
            "cluster_bw_min",
            "cluster_bw_max",
            "cluster_latency_us",
            "cluster_tiers",
        ]
        .map(String::from),
    );
    assert_eq!(names.len(), FEATURE_DIM);
    names
}

/// Which Table-3 row an encoded column belongs to, if any (used to
/// aggregate per-column importance into the paper's data-feature rows).
pub fn table3_group(col: usize) -> Option<&'static str> {
    match col {
        0 => Some("The number of Vertex"),
        1 => Some("The number of Edge"),
        2..=7 => Some("In-degree"),
        8..=13 => Some("Out-degree"),
        14 | 15 => Some("Graph direction"),
        _ => None,
    }
}

/// Which Table-4 row an encoded column belongs to, if any.
pub fn table4_group(col: usize) -> Option<&'static str> {
    if (16..37).contains(&col) {
        Some(OpKey::all()[col - 16].name())
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::data::DataFeatures;

    fn task() -> TaskFeatures {
        let mut rng = crate::util::rng::Rng::new(420);
        let g = crate::graph::gen::chung_lu::generate("t", 300, 2000, 2.2, true, &mut rng);
        let data = DataFeatures::of(&g);
        TaskFeatures::from_vector(data, [10.0; crate::analyzer::NUM_OP_KEYS])
    }

    #[test]
    fn dimension_and_names_agree() {
        let t = task();
        let v = encode(&t, Strategy::Hybrid);
        assert_eq!(v.len(), FEATURE_DIM);
        assert_eq!(feature_names().len(), FEATURE_DIM);
    }

    /// The buffer-reuse path is the same encoding: writing two
    /// different strategies into one buffer leaves exactly the second
    /// strategy's vector (every slot is overwritten, none is stale).
    #[test]
    fn encode_into_reused_buffer_matches_encode() {
        let t = task();
        let mut buf = [0.0; FEATURE_DIM];
        encode_into(&t, Strategy::Ginger, &mut buf);
        assert_eq!(buf, encode(&t, Strategy::Ginger));
        encode_into(&t, Strategy::OneDSrc, &mut buf);
        assert_eq!(buf, encode(&t, Strategy::OneDSrc));
    }

    #[test]
    fn strategy_onehot_position() {
        let t = task();
        let names = feature_names();
        for (i, s) in Strategy::inventory().into_iter().enumerate() {
            let v = encode(&t, s);
            let hot: Vec<usize> =
                (37..48).filter(|&c| v[c] == 1.0).collect();
            assert_eq!(hot, vec![37 + i], "{}", s.name());
            assert_eq!(names[37 + i], format!("strategy_{}", s.name().to_lowercase()));
        }
    }

    #[test]
    fn sign_split_encoding() {
        let mut t = task();
        t.data.in_deg.skewness = -2.0;
        let v = encode(&t, Strategy::Random);
        assert_eq!(v[4], -1.0, "skew sign column");
        assert!((v[5] - (3.0f64).ln()).abs() < 1e-12, "log1p(|skew|)");
    }

    #[test]
    fn direction_onehot() {
        let mut t = task();
        t.data.directed = false;
        let v = encode(&t, Strategy::Random);
        assert_eq!((v[14], v[15]), (1.0, 0.0));
        t.data.directed = true;
        let v = encode(&t, Strategy::Random);
        assert_eq!((v[14], v[15]), (0.0, 1.0));
    }

    #[test]
    fn group_mappings_cover_tables() {
        assert_eq!(table3_group(0), Some("The number of Vertex"));
        assert_eq!(table3_group(9), Some("Out-degree"));
        assert_eq!(table3_group(16), None);
        assert_eq!(table4_group(16), Some("NUM_VERTEX"));
        assert_eq!(table4_group(36), Some("APPLY"));
        assert_eq!(table4_group(37), None);
    }

    #[test]
    fn hdrf_variants_share_family_but_not_onehot() {
        let t = task();
        let a = encode(&t, Strategy::Hdrf(10));
        let b = encode(&t, Strategy::Hdrf(100));
        assert_ne!(a[37..48], b[37..48]);
        assert_eq!(a[48..], b[48..]);
    }

    /// The cluster block occupies the trailing columns: default specs
    /// encode the uniform paper cluster, and a heterogeneous spec
    /// changes *only* those columns, leaving every pinned paper column
    /// untouched.
    #[test]
    fn cluster_block_is_appended_after_paper_columns() {
        use crate::engine::cluster::{ClusterSpec, CLUSTER_FEATURE_DIM};
        let names = feature_names();
        assert_eq!(names[52], "cluster_speed_min");
        assert_eq!(names[FEATURE_DIM - 1], "cluster_tiers");
        assert_eq!(FEATURE_DIM, 52 + CLUSTER_FEATURE_DIM);

        let t = task();
        let base = encode(&t, Strategy::Hybrid);
        let mut het = t.clone();
        het.cluster = ClusterSpec::straggler(0, 8.0).features();
        let v = encode(&het, Strategy::Hybrid);
        assert_eq!(base[..52], v[..52], "paper columns unchanged");
        assert_ne!(base[52..], v[52..], "cluster columns respond to spec");
        // uniform default: min == max speed, zero cv, two tiers
        assert_eq!(base[52], base[53]);
        assert_eq!(base[54], 0.0);
        assert_eq!(base[FEATURE_DIM - 1], 2.0);
        // straggler: speed spread appears
        assert!(v[52] < v[53]);
        assert!(v[54] > 0.0);
    }

    /// The wire transport image round-trips every field bit-exactly,
    /// so a task shipped to the selection daemon re-encodes to the
    /// identical model input on the other side.
    #[test]
    fn task_wire_image_roundtrips_bit_exactly() {
        let mut t = task();
        // awkward values that would not survive a lossy text round trip
        t.data.in_deg.skewness = -0.0;
        t.data.out_deg.kurtosis = 1.0e-300;
        t.algo[3] = f64::MIN_POSITIVE;
        let mut vals = [0.0; TASK_WIRE_DIM];
        task_to_values(&t, &mut vals);
        let mut back = zeroed_task();
        task_from_values(&vals, &mut back);
        assert_eq!(back.data.directed, t.data.directed);
        assert_eq!(back.data.in_deg.skewness.to_bits(), (-0.0f64).to_bits());
        for s in Strategy::INVENTORY {
            let a = encode(&t, s);
            let b = encode(&back, s);
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}", s.name());
            }
        }
        // the decode target is reused: a second decode overwrites
        // every slot, none is stale
        let u = task();
        let mut vals2 = [0.0; TASK_WIRE_DIM];
        task_to_values(&u, &mut vals2);
        task_from_values(&vals2, &mut back);
        assert_eq!(back.data.num_edges.to_bits(), u.data.num_edges.to_bits());
        assert_eq!(back.algo, u.algo);
    }
}
