//! CSR lookup equivalence: `LocalEdges::out_of`/`in_of` must return
//! exactly what the pre-CSR sorted-slice `group()` implementation
//! returned — same pairs, same order — for every vertex, worker,
//! strategy and graph shape. The reference below *is* that
//! implementation: two independently sorted copies of the worker's
//! edges, with each vertex's group found by binary search
//! (`partition_point` on both bounds).

use gps_select::engine::worker::{build_local_edges, build_local_edges_for, LocalEdges};
use gps_select::graph::{Edge, Graph};
use gps_select::partition::{Partitioning, Strategy};
use gps_select::util::rng::Rng;

/// The pre-CSR layout: one worker's edges sorted `(src, dst)` and
/// `(dst, src)`, looked up by binary search per vertex.
struct SortedCopies {
    by_src: Vec<Edge>,
    by_dst: Vec<Edge>,
}

impl SortedCopies {
    fn build(g: &Graph, p: &Partitioning, w: usize) -> SortedCopies {
        let mut by_src = Vec::new();
        let mut by_dst = Vec::new();
        for (e, &(u, v)) in g.edges().iter().enumerate() {
            if p.edge_worker[e] as usize == w {
                by_src.push((u, v));
                by_dst.push((v, u));
            }
        }
        by_src.sort_unstable();
        by_dst.sort_unstable();
        SortedCopies { by_src, by_dst }
    }

    fn group(list: &[Edge], v: u32) -> &[Edge] {
        let lo = list.partition_point(|&(a, _)| a < v);
        let hi = list.partition_point(|&(a, _)| a <= v);
        &list[lo..hi]
    }
}

fn assert_equivalent(g: &Graph, p: &Partitioning, locals: &[LocalEdges], tag: &str) {
    for (w, l) in locals.iter().enumerate() {
        let reference = SortedCopies::build(g, p, w);
        assert_eq!(l.out_pairs(), &reference.by_src[..], "{tag}: worker {w} out sweep order");
        assert_eq!(l.in_pairs(), &reference.by_dst[..], "{tag}: worker {w} in sweep order");
        for v in 0..g.num_vertices() as u32 {
            assert_eq!(
                l.out_of(v),
                SortedCopies::group(&reference.by_src, v),
                "{tag}: out_of({v}) on worker {w}"
            );
            assert_eq!(
                l.in_of(v),
                SortedCopies::group(&reference.by_dst, v),
                "{tag}: in_of({v}) on worker {w}"
            );
        }
        // lookups past the vertex space are empty, not a panic
        assert!(l.out_of(g.num_vertices() as u32 + 7).is_empty());
        assert!(l.in_of(u32::MAX).is_empty());
    }
}

fn strategies() -> Vec<Strategy> {
    vec![
        Strategy::OneDSrc,
        Strategy::Random,
        Strategy::TwoD,
        Strategy::Hdrf(50),
        Strategy::Ginger,
    ]
}

#[test]
fn csr_matches_sorted_slices_on_random_graphs() {
    let mut rng = Rng::new(0xc5e);
    for directed in [true, false] {
        let g = gps_select::graph::gen::erdos::generate("csr-er", 120, 700, directed, &mut rng);
        for s in strategies() {
            for workers in [1usize, 3, 8] {
                let p = s.partition(&g, workers);
                let locals = build_local_edges(&g, &p);
                assert_equivalent(&g, &p, &locals, &format!("erdos d={directed} {workers}w"));
            }
        }
    }
}

#[test]
fn csr_matches_sorted_slices_on_skewed_graphs() {
    let mut rng = Rng::new(0xc5f);
    let g = gps_select::graph::gen::chung_lu::generate("csr-cl", 150, 900, 2.2, true, &mut rng);
    for s in strategies() {
        let p = s.partition(&g, 6);
        let locals = build_local_edges(&g, &p);
        assert_equivalent(&g, &p, &locals, "chung-lu");
    }
}

/// Frontier-style shapes: a long cycle (every vertex degree 2, long
/// runs of single-edge groups) and a star (one vertex owns every
/// group), plus an isolated-vertex tail the dense offsets must cover.
#[test]
fn csr_matches_sorted_slices_on_frontier_shapes() {
    let n = 64u32;
    let cycle: Vec<Edge> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    let star: Vec<Edge> = (1..n).map(|i| (0, i)).collect();
    for (name, edges) in [("cycle", cycle), ("star", star)] {
        // 16 trailing isolated vertices
        let g = Graph::from_edges(name, n as usize + 16, edges, true);
        for s in strategies() {
            let p = s.partition(&g, 4);
            let locals = build_local_edges(&g, &p);
            assert_equivalent(&g, &p, &locals, name);
            // the single-worker builder agrees with the full build
            for rank in 0..4 {
                let one = build_local_edges_for(&g, &p, rank);
                assert_eq!(one.out_pairs(), locals[rank].out_pairs(), "{name} rank {rank}");
                assert_eq!(one.in_pairs(), locals[rank].in_pairs(), "{name} rank {rank}");
            }
        }
    }
}
