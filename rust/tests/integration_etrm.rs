//! Integration tests across features + dataset + ml + etrm: the
//! learning half of the pipeline, including generalisation splits and
//! failure-injection cases.

use gps_select::algorithms::Algorithm;
use gps_select::dataset::augment::augment;
use gps_select::dataset::logs::LogStore;
use gps_select::engine::cluster::ClusterSpec;
use gps_select::etrm::Etrm;
use gps_select::features::{encode, FEATURE_DIM};
use gps_select::graph::datasets::DatasetSpec;
use gps_select::ml::gbdt::GbdtParams;
use gps_select::ml::metrics::spearman;
use gps_select::ml::Label;
use gps_select::partition::Strategy;

fn small_corpus(scale: f64) -> LogStore {
    let cfg = ClusterSpec::with_workers(16);
    let mut store = LogStore::default();
    for name in ["wiki", "epinions", "facebook", "gd-ro"] {
        let g = DatasetSpec::by_name(name).unwrap().build(scale, 7);
        store
            .record_graph(
                &g,
                &[Algorithm::Aid, Algorithm::Pr, Algorithm::Tc, Algorithm::Gc],
                &Strategy::inventory(),
                &cfg,
            )
            .unwrap();
    }
    store
}

/// Train on three graphs, evaluate ordering quality on the held-out
/// fourth (the generalisation the paper's test set B measures).
#[test]
fn generalises_to_unseen_graph() {
    let store = small_corpus(0.01);
    let train_logs: Vec<_> =
        store.logs.iter().filter(|l| l.graph != "gd-ro").cloned().collect();
    let synth_store = LogStore::from_parts(train_logs, store.graph_features.clone());
    let synthetic = augment(&synth_store, 2..=6, Some(8000), 1);
    assert!(!synthetic.is_empty());
    let etrm = Etrm::train_gbdt(
        &synthetic,
        GbdtParams { n_estimators: 200, max_depth: 8, ..GbdtParams::paper() },
        Label::SimTime,
    );
    // rank correlation between predicted and real times on the unseen
    // graph must be clearly positive for the expensive algorithms
    for algo in [Algorithm::Pr, Algorithm::Tc] {
        let task = store
            .logs
            .iter()
            .find(|l| l.graph == "gd-ro" && l.algorithm == algo.name())
            .unwrap();
        let preds: Vec<f64> = Strategy::inventory()
            .iter()
            .map(|s| etrm.predict(&task.features, *s))
            .collect();
        let truth = store.times_of_task("gd-ro", algo.name()).unwrap();
        let rho = spearman(&preds, &truth);
        assert!(rho > 0.0, "{}: spearman {rho} (preds {preds:?}, truth {truth:?})", algo.name());
    }
}

/// Predicted times must scale with the algorithm's cost tier even for a
/// synthetic mega-task (feature aggregation semantics).
#[test]
fn synthetic_tasks_predict_larger_times() {
    let store = small_corpus(0.008);
    let synthetic = augment(&store, 2..=5, Some(6000), 2);
    let etrm = Etrm::train_gbdt(
        &synthetic,
        GbdtParams { n_estimators: 120, max_depth: 8, ..GbdtParams::fast() },
        Label::SimTime,
    );
    let aid = store
        .logs
        .iter()
        .find(|l| l.graph == "wiki" && l.algorithm == "AID")
        .unwrap();
    let pr = store
        .logs
        .iter()
        .find(|l| l.graph == "wiki" && l.algorithm == "PR")
        .unwrap();
    let combined = gps_select::features::TaskFeatures::aggregate_algos(
        aid.features.data,
        &[aid.features.algo, pr.features.algo, pr.features.algo],
    );
    let t_aid = etrm.predict(&aid.features, Strategy::Random);
    let t_combined = etrm.predict(&combined, Strategy::Random);
    assert!(
        t_combined > t_aid,
        "mega-task {t_combined} must exceed single AID {t_aid}"
    );
}

/// Encoding must be stable: same task+strategy → same vector; the
/// feature dimension is pinned so an artifact built under a stale
/// schema cannot silently load (52 paper columns + the cluster block).
#[test]
fn encoding_stability_and_dimension() {
    let store = small_corpus(0.008);
    let l = &store.logs[0];
    let a = encode(&l.features, l.strategy);
    let b = encode(&l.features, l.strategy);
    assert_eq!(a, b);
    assert_eq!(
        FEATURE_DIM,
        52 + gps_select::engine::cluster::CLUSTER_FEATURE_DIM,
        "pinned feature schema changed"
    );
}

/// Failure injection: training on an empty log set must panic loudly
/// (not silently produce a broken model).
#[test]
#[should_panic(expected = "empty")]
fn empty_training_set_panics() {
    Etrm::train_gbdt(&[], GbdtParams::fast(), Label::SimTime);
}

/// The measured wall-clock label channel trains end to end: same
/// features, genuinely different targets, finite positive predictions,
/// and a valid selection.
#[test]
fn wall_clock_label_channel_trains() {
    use gps_select::etrm::model::encode_logs;
    let store = small_corpus(0.008);
    let synthetic = augment(&store, 2..=4, Some(4000), 3);
    assert!(!synthetic.is_empty());
    let sim = encode_logs(&synthetic, Label::SimTime);
    let wall = encode_logs(&synthetic, Label::WallClock);
    assert_eq!(sim.len(), wall.len());
    assert_eq!(sim.label, Label::SimTime);
    assert_eq!(wall.label, Label::WallClock);
    assert!(wall.y.iter().all(|&v| v > 0.0 && v.is_finite()));
    assert_ne!(sim.y, wall.y, "oracle seconds vs measured milliseconds");
    let etrm = Etrm::train_gbdt(
        &synthetic,
        GbdtParams { n_estimators: 40, max_depth: 6, ..GbdtParams::fast() },
        Label::WallClock,
    );
    assert_eq!(etrm.label, Label::WallClock);
    let preds: Vec<f64> = Strategy::inventory()
        .iter()
        .map(|s| etrm.predict(&store.logs[0].features, *s))
        .collect();
    assert!(preds.iter().all(|t| t.is_finite() && *t > 0.0), "{preds:?}");
    assert!(Strategy::inventory().contains(&etrm.select(&store.logs[0].features)));
}

/// Selection works even when all candidate times are identical
/// (degenerate logs): any inventory strategy is acceptable.
#[test]
fn degenerate_equal_times_still_selects() {
    let store = small_corpus(0.008);
    let mut logs = store.logs.clone();
    for l in &mut logs {
        l.time = 1.0;
    }
    let etrm = Etrm::train_gbdt(
        &logs,
        GbdtParams { n_estimators: 30, max_depth: 4, ..GbdtParams::fast() },
        Label::SimTime,
    );
    let s = etrm.select(&store.logs[0].features);
    assert!(Strategy::inventory().contains(&s));
}
