//! Integration tests across graph + partition + engine + algorithms:
//! the engine's global guarantees on realistic corpus graphs.

use gps_select::algorithms::Algorithm;
use gps_select::engine::cluster::ClusterSpec;
use gps_select::graph::datasets::DatasetSpec;
use gps_select::partition::Strategy;

/// Results are bit-identical across all 12 strategies and several
/// worker counts, for every algorithm, on a real corpus graph.
#[test]
fn results_invariant_across_strategies_and_workers() {
    let g = DatasetSpec::by_name("wiki").unwrap().build(0.008, 123);
    let reference: Vec<f64> = {
        let cfg = ClusterSpec::with_workers(1);
        let p = Strategy::OneDSrc.partition(&g, 1);
        Algorithm::all().iter().map(|a| a.simulate(&g, &p, &cfg).checksum).collect()
    };
    for &workers in &[4usize, 64] {
        let cfg = ClusterSpec::with_workers(workers);
        for s in Strategy::all() {
            let p = s.partition(&g, workers);
            for (i, a) in Algorithm::all().iter().enumerate() {
                let got = a.simulate(&g, &p, &cfg).checksum;
                assert!(
                    (got - reference[i]).abs() <= 1e-9 * (1.0 + reference[i].abs()),
                    "{}/{} at {workers} workers: {} vs {}",
                    a.name(),
                    s.name(),
                    got,
                    reference[i]
                );
            }
        }
    }
}

/// The motivation claim (Fig 1): across tasks, the best strategy is not
/// constant — at least two different strategies win somewhere. Run at
/// the default experiment scale (1/32); at much smaller scales the
/// balance-dominant strategies win everything and the paper's dynamics
/// disappear.
#[test]
fn best_strategy_differs_per_task() {
    let cfg = ClusterSpec::with_workers(64);
    let mut winners = std::collections::BTreeSet::new();
    for (gname, algo) in
        [("stanford", Algorithm::Pr), ("stanford", Algorithm::Tc), ("gd-hu", Algorithm::Apcn)]
    {
        let g = DatasetSpec::by_name(gname).unwrap().build(1.0 / 32.0, 42);
        let mut best: Option<(Strategy, f64)> = None;
        for s in Strategy::inventory() {
            let p = s.partition(&g, 64);
            let t = algo.simulate(&g, &p, &cfg).sim.total;
            if best.map_or(true, |(_, bt)| t < bt) {
                best = Some((s, t));
            }
        }
        winners.insert(best.unwrap().0.name());
    }
    assert!(winners.len() >= 2, "only one winner across tasks: {winners:?}");
}

/// Scalability (Fig 4 shape): 64 workers beat 4 workers on a
/// compute-heavy workload.
#[test]
fn more_workers_scale_on_stanford() {
    let g = DatasetSpec::by_name("stanford").unwrap().build(0.008, 42);
    let time = |w: usize| {
        let cfg = ClusterSpec::with_workers(w);
        let p = Strategy::TwoD.partition(&g, w);
        Algorithm::Pr.simulate(&g, &p, &cfg).sim.total
    };
    let t4 = time(4);
    let t64 = time(64);
    assert!(t64 < t4, "PR: 64w {t64} should beat 4w {t4}");
}

/// Cost-model channels: a deliberately imbalanced partitioning (all
/// edges on one worker) must simulate slower than a balanced one.
#[test]
fn imbalance_costs_time() {
    let g = DatasetSpec::by_name("epinions").unwrap().build(0.008, 42);
    let cfg = ClusterSpec::with_workers(8);
    let balanced = Strategy::Hdrf(100).partition(&g, 8);
    let skewed = gps_select::partition::Partitioning::from_edge_assignment(
        &g,
        8,
        vec![0u16; g.num_edges()],
    );
    let tb = Algorithm::Pr.simulate(&g, &balanced, &cfg).sim.total;
    let ts = Algorithm::Pr.simulate(&g, &skewed, &cfg).sim.total;
    assert!(ts > 2.0 * tb, "skewed {ts} vs balanced {tb}");
}

/// APCN on a web graph dwarfs the cheap algorithms (Table 7 hierarchy).
#[test]
fn cost_hierarchy_matches_table7() {
    let g = DatasetSpec::by_name("stanford").unwrap().build(0.008, 42);
    let cfg = ClusterSpec::with_workers(64);
    let p = Strategy::Random.partition(&g, 64);
    let t = |a: Algorithm| a.simulate(&g, &p, &cfg).sim.total;
    let (aid, pr, apcn, rw) = (t(Algorithm::Aid), t(Algorithm::Pr), t(Algorithm::Apcn), t(Algorithm::Rw));
    assert!(pr > aid, "PR {pr} > AID {aid}");
    assert!(apcn > pr, "APCN {apcn} > PR {pr}");
    assert!(rw < pr, "RW {rw} < PR {pr}");
}
