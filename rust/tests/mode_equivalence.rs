//! Execution-mode equivalence: `ExecutionMode::Threaded` (real
//! thread-per-worker message passing over mpsc channels) **and**
//! `ExecutionMode::Socket` (one worker process per engine worker over
//! localhost TCP, envelopes serialized through `engine::wire`) must be
//! **bit-identical** to `ExecutionMode::Simulated` (the sequential
//! cost-model oracle) — final vertex values (compared through the
//! bit-exact `value_hash` digest), the full `OpCounts`, and the
//! simulated-time label — for every algorithm, across partitioning
//! strategies and worker counts. This is the property that lets the
//! simulated labels stand in for measured multi-worker execution, and
//! (for the socket mode) proves the wire format loses no bits.

use gps_select::algorithms::Algorithm;
use gps_select::engine::cluster::ClusterSpec;
use gps_select::engine::transport::socket;
use gps_select::engine::ExecutionMode;
use gps_select::graph::Graph;
use gps_select::partition::Strategy;
use gps_select::util::rng::Rng;

/// The socket backend spawns worker processes; point it at the repro
/// CLI, which installs the `--worker-rank` hook (the test binary's
/// libtest main does not).
fn use_repro_workers() {
    socket::set_worker_binary(env!("CARGO_BIN_EXE_repro"));
}

fn assert_modes_agree(g: &Graph, strategies: &[Strategy], workers: &[usize]) {
    for &w in workers {
        assert_modes_agree_with(g, strategies, &ClusterSpec::with_workers(w));
    }
}

fn assert_modes_agree_with(g: &Graph, strategies: &[Strategy], cfg: &ClusterSpec) {
    use_repro_workers();
    {
        let w = cfg.num_workers();
        for &s in strategies {
            let p = s.partition(g, w);
            for a in Algorithm::all() {
                let sim = a.execute(g, &p, cfg, ExecutionMode::Simulated);
                for mode in [ExecutionMode::Threaded, ExecutionMode::Socket] {
                    let other = a.execute(g, &p, cfg, mode);
                    let ctx = format!(
                        "{}/{}/{} at {w} workers ({} mode)",
                        g.name,
                        a.name(),
                        s.name(),
                        mode.name()
                    );
                    assert_eq!(
                        sim.value_hash, other.value_hash,
                        "{ctx}: values must be bit-identical"
                    );
                    assert_eq!(sim.ops, other.ops, "{ctx}: op counts must match");
                    assert_eq!(
                        sim.sim.total.to_bits(),
                        other.sim.total.to_bits(),
                        "{ctx}: simulated time must be bit-identical ({} vs {})",
                        sim.sim.total,
                        other.sim.total
                    );
                    assert_eq!(
                        sim.checksum.to_bits(),
                        other.checksum.to_bits(),
                        "{ctx}: checksums must match"
                    );
                    // the measured label is present in every mode (and
                    // is the one field allowed to differ)
                    assert!(
                        other.wall_clock_ms > 0.0 && other.wall_clock_ms.is_finite(),
                        "{ctx}: wall clock {}",
                        other.wall_clock_ms
                    );
                }
            }
        }
    }
}

/// All 8 algorithms × 3 strategies × {1, 2, 4} workers on a directed
/// power-law graph, across **all three** execution modes — the full
/// acceptance matrix.
#[test]
fn threaded_and_socket_are_bit_identical_to_simulated_directed() {
    let mut rng = Rng::new(4242);
    let g = gps_select::graph::gen::chung_lu::generate("mode-eq-d", 400, 2400, 2.2, true, &mut rng);
    assert_modes_agree(
        &g,
        &[Strategy::Random, Strategy::Hdrf(50), Strategy::TwoD],
        &[1, 2, 4],
    );
}

/// Undirected graphs exercise the both-direction sweeps (GC/TC/CC
/// semantics differ from the directed case) and a different strategy
/// slice, including the degree-differentiated Hybrid cut.
#[test]
fn threaded_and_socket_are_bit_identical_to_simulated_undirected() {
    let mut rng = Rng::new(4243);
    let g = gps_select::graph::gen::erdos::generate("mode-eq-u", 300, 1500, false, &mut rng);
    assert_modes_agree(&g, &[Strategy::Hybrid, Strategy::Ginger, Strategy::OneDDst], &[2, 4]);
}

/// The activation frontier path (RW's scatter + reactivate_self) on a
/// sparse walk-friendly graph, at a worker count that does not divide
/// the vertex count evenly.
#[test]
fn threaded_matches_on_activation_frontiers() {
    let n = 96u32;
    let edges: Vec<(u32, u32)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    let g = Graph::from_edges("mode-eq-cycle", n as usize, edges, true);
    assert_modes_agree(&g, &[Strategy::Random, Strategy::CanonicalRandom], &[1, 3]);
}

/// A genuinely heterogeneous cluster — one 4× straggler worker, two
/// machines, asymmetric link tiers — must not break transport
/// equivalence: the cost model charges every mode through the same
/// ledger, so values, op counts and the simulated label stay
/// bit-identical across Simulated / Threaded / Socket.
#[test]
fn straggler_cluster_stays_bit_identical_across_modes() {
    let mut rng = Rng::new(4244);
    let g =
        gps_select::graph::gen::chung_lu::generate("mode-eq-het", 300, 1800, 2.1, true, &mut rng);
    let cfg = ClusterSpec::builder()
        .workers(4)
        .machines(2)
        .uniform_speed(2.0e6)
        .speed(1, 5.0e5)
        .inter_link(6.0e8, 9.0e-6)
        .intra_link(8.0e9, 1.0e-6)
        .build()
        .unwrap();
    assert_modes_agree_with(&g, &[Strategy::Random, Strategy::Hybrid], &cfg);
}

/// The committed uniform-vs-straggler spec pair is cluster-conditional
/// end to end: (a) the simulated oracle's best strategy flips on at
/// least one (graph, algorithm) task, and (b) an ETRM trained on the
/// union of both corpora — whose logs carry the cluster feature block —
/// reproduces a flip from the features alone. This is the pinned
/// acceptance pair for the heterogeneity-aware selection API.
#[test]
fn uniform_vs_straggler_specs_flip_selection() {
    use gps_select::dataset::logs::{ExecutionLog, LogStore};
    use gps_select::etrm::Etrm;
    use gps_select::graph::datasets::DatasetSpec;
    use gps_select::ml::gbdt::GbdtParams;
    use gps_select::ml::Label;

    let uniform = ClusterSpec::with_workers(8);
    // the committed skew: worker 0 runs 64× slower, so compute on the
    // straggler dominates and the oracle favours whichever strategy
    // keeps load off it — not the uniform cluster's comm-optimal pick
    let straggler = ClusterSpec::builder().workers(8).speed(0, 2.0e6 / 64.0).build().unwrap();

    let graphs = ["wiki", "facebook"];
    let algos = Algorithm::all();
    let strategies = Strategy::inventory();
    let mut stores: Vec<LogStore> = Vec::new();
    for cfg in [&uniform, &straggler] {
        let mut store = LogStore::default();
        for name in graphs {
            let g = DatasetSpec::by_name(name).unwrap().build(0.01, 7);
            store.record_graph(&g, &algos, &strategies, cfg).unwrap();
        }
        stores.push(store);
    }

    // (a) the simulated oracle flips its argmin on ≥ 1 task
    let oracle_best = |store: &LogStore, graph: &str, algo: &str| -> Strategy {
        strategies
            .iter()
            .copied()
            .min_by(|&x, &y| {
                let tx = store.time_of(graph, algo, x).unwrap();
                let ty = store.time_of(graph, algo, y).unwrap();
                tx.partial_cmp(&ty).unwrap()
            })
            .unwrap()
    };
    let mut oracle_flips = 0usize;
    for name in graphs {
        for a in &algos {
            let u = oracle_best(&stores[0], name, a.name());
            let s = oracle_best(&stores[1], name, a.name());
            if u != s {
                oracle_flips += 1;
            }
        }
    }
    assert!(
        oracle_flips > 0,
        "a 64× straggler must change the oracle-best strategy on at least one task"
    );

    // (b) a high-capacity in-sample ETRM reproduces a flip from the
    // cluster feature block alone (the only columns that differ
    // between the two corpora's copies of the same task)
    let union: Vec<ExecutionLog> =
        stores[0].logs.iter().chain(stores[1].logs.iter()).cloned().collect();
    let etrm = Etrm::train_gbdt(
        &union,
        GbdtParams { n_estimators: 300, max_depth: 10, ..GbdtParams::fast() },
        Label::SimTime,
    );
    let mut model_flips = 0usize;
    for name in graphs {
        for a in &algos {
            let task_of = |store: &LogStore| {
                store
                    .logs
                    .iter()
                    .find(|l| l.graph == name && l.algorithm == a.name())
                    .unwrap()
                    .features
                    .clone()
            };
            if etrm.select(&task_of(&stores[0])) != etrm.select(&task_of(&stores[1])) {
                model_flips += 1;
            }
        }
    }
    assert!(
        model_flips > 0,
        "the trained ETRM must select differently under the straggler cluster features \
         (oracle flipped {oracle_flips} of {} tasks)",
        graphs.len() * algos.len()
    );
}
