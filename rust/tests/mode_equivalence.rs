//! Execution-mode equivalence: `ExecutionMode::Threaded` (real
//! thread-per-worker message passing over mpsc channels) must be
//! **bit-identical** to `ExecutionMode::Simulated` (the sequential
//! cost-model oracle) — final vertex values (compared through the
//! bit-exact `value_hash` digest), the full `OpCounts`, and the
//! simulated-time label — for every algorithm, across partitioning
//! strategies and worker counts. This is the property that lets the
//! simulated labels stand in for measured multi-worker execution.

use gps_select::algorithms::Algorithm;
use gps_select::engine::cost::ClusterConfig;
use gps_select::engine::ExecutionMode;
use gps_select::graph::Graph;
use gps_select::partition::Strategy;
use gps_select::util::rng::Rng;

fn assert_modes_agree(g: &Graph, strategies: &[Strategy], workers: &[usize]) {
    for &w in workers {
        let cfg = ClusterConfig::with_workers(w);
        for &s in strategies {
            let p = s.partition(g, w);
            for a in Algorithm::all() {
                let sim = a.execute(g, &p, &cfg, ExecutionMode::Simulated);
                let thr = a.execute(g, &p, &cfg, ExecutionMode::Threaded);
                let ctx = format!("{}/{}/{} at {w} workers", g.name, a.name(), s.name());
                assert_eq!(
                    sim.value_hash, thr.value_hash,
                    "{ctx}: values must be bit-identical"
                );
                assert_eq!(sim.ops, thr.ops, "{ctx}: op counts must match");
                assert_eq!(
                    sim.sim.total.to_bits(),
                    thr.sim.total.to_bits(),
                    "{ctx}: simulated time must be bit-identical ({} vs {})",
                    sim.sim.total,
                    thr.sim.total
                );
                assert_eq!(
                    sim.checksum.to_bits(),
                    thr.checksum.to_bits(),
                    "{ctx}: checksums must match"
                );
            }
        }
    }
}

/// All 8 algorithms × 3 strategies × {1, 2, 4} workers on a directed
/// power-law graph — the full acceptance matrix.
#[test]
fn threaded_is_bit_identical_to_simulated_directed() {
    let mut rng = Rng::new(4242);
    let g = gps_select::graph::gen::chung_lu::generate("mode-eq-d", 400, 2400, 2.2, true, &mut rng);
    assert_modes_agree(
        &g,
        &[Strategy::Random, Strategy::Hdrf(50), Strategy::TwoD],
        &[1, 2, 4],
    );
}

/// Undirected graphs exercise the both-direction sweeps (GC/TC/CC
/// semantics differ from the directed case) and a different strategy
/// slice, including the degree-differentiated Hybrid cut.
#[test]
fn threaded_is_bit_identical_to_simulated_undirected() {
    let mut rng = Rng::new(4243);
    let g = gps_select::graph::gen::erdos::generate("mode-eq-u", 300, 1500, false, &mut rng);
    assert_modes_agree(&g, &[Strategy::Hybrid, Strategy::Ginger, Strategy::OneDDst], &[2, 4]);
}

/// The activation frontier path (RW's scatter + reactivate_self) on a
/// sparse walk-friendly graph, at a worker count that does not divide
/// the vertex count evenly.
#[test]
fn threaded_matches_on_activation_frontiers() {
    let n = 96u32;
    let edges: Vec<(u32, u32)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    let g = Graph::from_edges("mode-eq-cycle", n as usize, edges, true);
    assert_modes_agree(&g, &[Strategy::Random, Strategy::CanonicalRandom], &[1, 3]);
}
