//! Execution-mode equivalence: `ExecutionMode::Threaded` (real
//! thread-per-worker message passing over mpsc channels) **and**
//! `ExecutionMode::Socket` (one worker process per engine worker over
//! localhost TCP, envelopes serialized through `engine::wire`) must be
//! **bit-identical** to `ExecutionMode::Simulated` (the sequential
//! cost-model oracle) — final vertex values (compared through the
//! bit-exact `value_hash` digest), the full `OpCounts`, and the
//! simulated-time label — for every algorithm, across partitioning
//! strategies and worker counts. This is the property that lets the
//! simulated labels stand in for measured multi-worker execution, and
//! (for the socket mode) proves the wire format loses no bits.

use gps_select::algorithms::Algorithm;
use gps_select::engine::cost::ClusterConfig;
use gps_select::engine::transport::socket;
use gps_select::engine::ExecutionMode;
use gps_select::graph::Graph;
use gps_select::partition::Strategy;
use gps_select::util::rng::Rng;

/// The socket backend spawns worker processes; point it at the repro
/// CLI, which installs the `--worker-rank` hook (the test binary's
/// libtest main does not).
fn use_repro_workers() {
    socket::set_worker_binary(env!("CARGO_BIN_EXE_repro"));
}

fn assert_modes_agree(g: &Graph, strategies: &[Strategy], workers: &[usize]) {
    use_repro_workers();
    for &w in workers {
        let cfg = ClusterConfig::with_workers(w);
        for &s in strategies {
            let p = s.partition(g, w);
            for a in Algorithm::all() {
                let sim = a.execute(g, &p, &cfg, ExecutionMode::Simulated);
                for mode in [ExecutionMode::Threaded, ExecutionMode::Socket] {
                    let other = a.execute(g, &p, &cfg, mode);
                    let ctx = format!(
                        "{}/{}/{} at {w} workers ({} mode)",
                        g.name,
                        a.name(),
                        s.name(),
                        mode.name()
                    );
                    assert_eq!(
                        sim.value_hash, other.value_hash,
                        "{ctx}: values must be bit-identical"
                    );
                    assert_eq!(sim.ops, other.ops, "{ctx}: op counts must match");
                    assert_eq!(
                        sim.sim.total.to_bits(),
                        other.sim.total.to_bits(),
                        "{ctx}: simulated time must be bit-identical ({} vs {})",
                        sim.sim.total,
                        other.sim.total
                    );
                    assert_eq!(
                        sim.checksum.to_bits(),
                        other.checksum.to_bits(),
                        "{ctx}: checksums must match"
                    );
                    // the measured label is present in every mode (and
                    // is the one field allowed to differ)
                    assert!(
                        other.wall_clock_ms > 0.0 && other.wall_clock_ms.is_finite(),
                        "{ctx}: wall clock {}",
                        other.wall_clock_ms
                    );
                }
            }
        }
    }
}

/// All 8 algorithms × 3 strategies × {1, 2, 4} workers on a directed
/// power-law graph, across **all three** execution modes — the full
/// acceptance matrix.
#[test]
fn threaded_and_socket_are_bit_identical_to_simulated_directed() {
    let mut rng = Rng::new(4242);
    let g = gps_select::graph::gen::chung_lu::generate("mode-eq-d", 400, 2400, 2.2, true, &mut rng);
    assert_modes_agree(
        &g,
        &[Strategy::Random, Strategy::Hdrf(50), Strategy::TwoD],
        &[1, 2, 4],
    );
}

/// Undirected graphs exercise the both-direction sweeps (GC/TC/CC
/// semantics differ from the directed case) and a different strategy
/// slice, including the degree-differentiated Hybrid cut.
#[test]
fn threaded_and_socket_are_bit_identical_to_simulated_undirected() {
    let mut rng = Rng::new(4243);
    let g = gps_select::graph::gen::erdos::generate("mode-eq-u", 300, 1500, false, &mut rng);
    assert_modes_agree(&g, &[Strategy::Hybrid, Strategy::Ginger, Strategy::OneDDst], &[2, 4]);
}

/// The activation frontier path (RW's scatter + reactivate_self) on a
/// sparse walk-friendly graph, at a worker count that does not divide
/// the vertex count evenly.
#[test]
fn threaded_matches_on_activation_frontiers() {
    let n = 96u32;
    let edges: Vec<(u32, u32)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
    let g = Graph::from_edges("mode-eq-cycle", n as usize, edges, true);
    assert_modes_agree(&g, &[Strategy::Random, Strategy::CanonicalRandom], &[1, 3]);
}
