//! The corpus-checkpoint contract: a build interrupted after N graphs
//! resumes from its checkpoint directory, recomputes only the remaining
//! graphs, and yields a store bit-identical to an uninterrupted
//! single-shot build — for any pool thread count and both engine
//! modes — while corrupted shards and configuration-mismatched
//! manifests are rejected instead of merged.

use std::path::PathBuf;

use gps_select::dataset::checkpoint::{manifest_text, CheckpointStore};
use gps_select::dataset::logs::LogStore;
use gps_select::engine::cluster::ClusterSpec;
use gps_select::engine::ExecutionMode;

const SCALE: f64 = 0.002;
const SEED: u64 = 7;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gps_resume_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Bit-exact store equality (same contract as determinism_threads).
fn assert_stores_identical(a: &LogStore, b: &LogStore) {
    assert_eq!(a.logs.len(), b.logs.len());
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.graph, y.graph);
        assert_eq!(x.algorithm, y.algorithm);
        assert_eq!(x.strategy, y.strategy);
        assert_eq!(
            x.time.to_bits(),
            y.time.to_bits(),
            "time bits differ for {}/{}/{}",
            x.graph,
            x.algorithm,
            x.strategy.name()
        );
        assert_eq!(x.features.algo, y.features.algo, "{}/{}", x.graph, x.algorithm);
        assert_eq!(x.features.data, y.features.data, "{}", x.graph);
    }
    assert_eq!(a.graph_features, b.graph_features);
}

#[test]
fn interrupted_build_resumes_bit_identical() {
    let cfg = ClusterSpec::with_workers(16);
    let clean =
        LogStore::build_corpus_parallel(SCALE, SEED, &cfg, 1, ExecutionMode::Simulated).unwrap();

    let dir = scratch("resume");
    // "interrupt" after 5 of the 12 graphs, on a different thread count
    // than the resume — content must not depend on either
    let done = LogStore::checkpoint_prefix(
        SCALE,
        SEED,
        &cfg,
        3,
        ExecutionMode::Simulated,
        &dir,
        5,
    )
    .unwrap();
    assert_eq!(done, 5);
    assert!(dir.join("manifest.txt").exists());
    let shards = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().file_name().to_string_lossy().ends_with(".shard")
        })
        .count();
    assert_eq!(shards, 5);

    // resume to completion
    let resumed = LogStore::build_corpus_checkpointed(
        SCALE,
        SEED,
        &cfg,
        2,
        ExecutionMode::Simulated,
        Some(dir.as_path()),
    )
    .unwrap();
    assert_stores_identical(&clean, &resumed);

    // the completed checkpoint now holds all 12 graphs; a fresh run
    // restores everything (zero recompute) and is still bit-identical
    let restored = LogStore::build_corpus_checkpointed(
        SCALE,
        SEED,
        &cfg,
        4,
        ExecutionMode::Simulated,
        Some(dir.as_path()),
    )
    .unwrap();
    assert_stores_identical(&clean, &restored);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn threaded_mode_resume_matches_simulated_reference() {
    let cfg = ClusterSpec::with_workers(4);
    let reference =
        LogStore::build_corpus_parallel(SCALE, SEED, &cfg, 1, ExecutionMode::Simulated).unwrap();
    let dir = scratch("threaded");
    let done =
        LogStore::checkpoint_prefix(SCALE, SEED, &cfg, 1, ExecutionMode::Threaded, &dir, 4)
            .unwrap();
    assert_eq!(done, 4);
    let resumed = LogStore::build_corpus_checkpointed(
        SCALE,
        SEED,
        &cfg,
        2,
        ExecutionMode::Threaded,
        Some(dir.as_path()),
    )
    .unwrap();
    // the two engine backends are bit-identical, so a threaded resumed
    // build must equal the simulated clean reference
    assert_stores_identical(&reference, &resumed);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Resume must actually *use* the checkpoint, not silently recompute:
/// tamper with a committed shard through the store API (so its checksum
/// stays valid) and check the tampered value flows into the resumed
/// corpus.
#[test]
fn resume_trusts_checkpointed_shards() {
    let cfg = ClusterSpec::with_workers(16);
    let dir = scratch("tamper");
    LogStore::checkpoint_prefix(SCALE, SEED, &cfg, 2, ExecutionMode::Simulated, &dir, 2)
        .unwrap();

    let manifest = manifest_text(SCALE, SEED, &cfg, ExecutionMode::Simulated);
    let store = CheckpointStore::open(&dir, &manifest).unwrap();
    let first = gps_select::graph::datasets::CORPUS[0].name;
    let (data, mut logs) = store.load(first).unwrap().unwrap();
    let marker = 12345.678_f64;
    logs[0].time = marker;
    store.save(first, &data, &logs).unwrap();

    let resumed = LogStore::build_corpus_checkpointed(
        SCALE,
        SEED,
        &cfg,
        2,
        ExecutionMode::Simulated,
        Some(dir.as_path()),
    )
    .unwrap();
    assert_eq!(
        resumed.logs[0].time.to_bits(),
        marker.to_bits(),
        "the resumed build recomputed a graph that was already checkpointed"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn mismatched_manifest_is_rejected_not_merged() {
    let cfg = ClusterSpec::with_workers(16);
    let dir = scratch("mismatch");
    LogStore::checkpoint_prefix(SCALE, SEED, &cfg, 2, ExecutionMode::Simulated, &dir, 1)
        .unwrap();

    // each fingerprinted knob, changed one at a time, must invalidate
    let other_workers = ClusterSpec::with_workers(8);
    let attempts: Vec<(&str, gps_select::util::error::Error)> = vec![
        (
            "scale",
            LogStore::build_corpus_checkpointed(
                0.003,
                SEED,
                &cfg,
                1,
                ExecutionMode::Simulated,
                Some(dir.as_path()),
            )
            .unwrap_err(),
        ),
        (
            "seed",
            LogStore::build_corpus_checkpointed(
                SCALE,
                SEED + 1,
                &cfg,
                1,
                ExecutionMode::Simulated,
                Some(dir.as_path()),
            )
            .unwrap_err(),
        ),
        (
            "workers",
            LogStore::build_corpus_checkpointed(
                SCALE,
                SEED,
                &other_workers,
                1,
                ExecutionMode::Simulated,
                Some(dir.as_path()),
            )
            .unwrap_err(),
        ),
        (
            "engine mode",
            LogStore::build_corpus_checkpointed(
                SCALE,
                SEED,
                &cfg,
                1,
                ExecutionMode::Threaded,
                Some(dir.as_path()),
            )
            .unwrap_err(),
        ),
    ];
    for (knob, err) in attempts {
        let msg = err.to_string();
        assert!(msg.contains("manifest mismatch"), "{knob}: {msg}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn truncated_shard_is_rejected() {
    let cfg = ClusterSpec::with_workers(16);
    let dir = scratch("truncate");
    LogStore::checkpoint_prefix(SCALE, SEED, &cfg, 2, ExecutionMode::Simulated, &dir, 1)
        .unwrap();
    let first = gps_select::graph::datasets::CORPUS[0].name;
    let path = dir.join(format!("{first}.shard"));
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();

    let err = LogStore::build_corpus_checkpointed(
        SCALE,
        SEED,
        &cfg,
        1,
        ExecutionMode::Simulated,
        Some(dir.as_path()),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("shard"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupted_shard_is_rejected() {
    let cfg = ClusterSpec::with_workers(16);
    let dir = scratch("corrupt");
    LogStore::checkpoint_prefix(SCALE, SEED, &cfg, 2, ExecutionMode::Simulated, &dir, 1)
        .unwrap();
    let first = gps_select::graph::datasets::CORPUS[0].name;
    let path = dir.join(format!("{first}.shard"));
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] = if bytes[mid] == b'0' { b'1' } else { b'0' };
    std::fs::write(&path, bytes).unwrap();

    let err = LogStore::build_corpus_checkpointed(
        SCALE,
        SEED,
        &cfg,
        1,
        ExecutionMode::Simulated,
        Some(dir.as_path()),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("shard"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}
