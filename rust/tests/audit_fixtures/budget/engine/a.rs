// Fixture: two non-test unwrap sites in engine scope.
pub fn f(x: Option<u32>, y: Option<u32>) -> u32 {
    x.unwrap() + y.unwrap()
}
