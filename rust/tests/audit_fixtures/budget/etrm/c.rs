// Fixture: unwrap outside the engine/dataset budget scope — never
// counted.
pub fn h(w: Option<u32>) -> u32 {
    w.unwrap()
}
