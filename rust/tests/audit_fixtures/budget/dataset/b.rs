// Fixture: one expect site in dataset scope, plus a test-only unwrap
// that must not count against the budget.
pub fn g(z: Option<u32>) -> u32 {
    z.expect("fixture")
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        super::g(Some(1u32.checked_add(2).unwrap()));
    }
}
