// Fixture: a justified allow suppresses the instant-now rule.
pub fn timed() -> f64 {
    // audit:allow(instant-now): latency report only, never a training label
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
