// Fixture: a bare allow fails and suppresses nothing.
pub fn timed() -> f64 {
    // audit:allow(instant-now)
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
