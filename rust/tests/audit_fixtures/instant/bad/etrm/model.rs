// Fixture: a wall-clock read outside the engine choke point.
pub fn timed() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
