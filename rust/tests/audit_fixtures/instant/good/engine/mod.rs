// Fixture: engine/mod.rs is the blessed Instant::now() site (the
// measured-label choke point), so the same read passes here.
pub fn timed() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
