// Fixture: a justified allow suppresses the float-fmt rule.
pub fn manifest(scale: f64) -> String {
    // audit:allow(float-fmt): debugging echo next to the exact hex bits
    format!("scale {scale}")
}
