// Fixture: the sanctioned path — f64 rendered via the exact-bits hex
// helper, never bare Display.
pub fn manifest(scale: f64) -> String {
    format!("scale {}", f64_hex(scale))
}
