// Fixture: a bare allow fails and suppresses nothing.
pub fn manifest(scale: f64) -> String {
    // audit:allow(float-fmt)
    format!("scale {scale}")
}
