// Fixture: Display-formatting an f64 into a persisted artifact loses
// bits; checkpoint/store/wire files must use f64_hex.
pub fn manifest(scale: f64) -> String {
    format!("scale {scale}")
}
