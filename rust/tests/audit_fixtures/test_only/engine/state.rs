// Fixture: every would-be violation sits inside a #[cfg(test)] region,
// which the audit skips entirely.
pub fn live() -> u32 {
    7
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn t() {
        let t0 = std::time::Instant::now();
        let mut m: HashMap<u32, f64> = HashMap::new();
        m.insert(1, t0.elapsed().as_secs_f64());
        let mut xs = [1.0f64, 0.5];
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
}
