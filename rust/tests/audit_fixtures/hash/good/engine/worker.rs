// Fixture: ordered collections pass in a determinism-critical module.
use std::collections::BTreeMap;

pub fn state() -> BTreeMap<u32, f64> {
    BTreeMap::new()
}
