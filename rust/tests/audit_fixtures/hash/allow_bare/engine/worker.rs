// Fixture: a bare allow (no justification) suppresses nothing and is
// itself a violation.
// audit:allow(hash-collections)
use std::collections::HashSet;
