// Fixture: HashMap named in a determinism-critical module.
use std::collections::HashMap;

pub fn state() -> HashMap<u32, f64> {
    HashMap::new()
}
