// Fixture: a justified allow suppresses the hash-collections rule.
// audit:allow(hash-collections): membership-only set, iteration order never observed
use std::collections::HashSet;

pub fn count_distinct(xs: &[u32]) -> usize {
    let set: HashSet<u32> = xs.iter().copied().collect(); // audit:allow(hash-collections): membership only
    set.len()
}
