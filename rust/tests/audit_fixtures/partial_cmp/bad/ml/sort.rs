// Fixture: partial_cmp chained into unwrap panics on NaN and is
// order-unstable; the rule applies in every module.
pub fn sort(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
