// Fixture: a bare allow fails and suppresses nothing.
pub fn sort(xs: &mut [f64]) {
    // audit:allow(partial-cmp)
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
