// Fixture: a justified allow suppresses the partial-cmp rule.
pub fn sort(xs: &mut [f64]) {
    // audit:allow(partial-cmp): inputs are proven finite by the caller
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
