// Fixture: total_cmp is the sanctioned total order over f64.
pub fn sort(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}
