//! Model-lifecycle gates (ISSUE 5 acceptance): for every serializable
//! backend, save → load → `predict_all` is bit-identical to the
//! in-memory model; corrupted/truncated artifacts and mismatched
//! manifests (feature schema, strategy inventory, label channel) are
//! rejected with clear errors; batched selection is equivalent to
//! sequential selection across pool thread counts; and the selector is
//! NaN-safe and deterministic for any regressor output.

use std::path::PathBuf;

use gps_select::algorithms::Algorithm;
use gps_select::dataset::logs::LogStore;
use gps_select::engine::cluster::ClusterSpec;
use gps_select::etrm::{store, Etrm, EtrmBackend};
use gps_select::features::TaskFeatures;
use gps_select::graph::datasets::DatasetSpec;
use gps_select::ml::gbdt::GbdtParams;
use gps_select::ml::mlp::MlpParams;
use gps_select::ml::{Label, Regressor};
use gps_select::partition::Strategy;
use gps_select::util::rng::fnv1a64;

fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("gps_model_store_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small real corpus: 2 graphs × 3 algorithms × the full inventory.
fn corpus() -> LogStore {
    let cfg = ClusterSpec::with_workers(8);
    let mut store = LogStore::default();
    for name in ["wiki", "epinions"] {
        let g = DatasetSpec::by_name(name).unwrap().build(0.008, 11);
        store
            .record_graph(
                &g,
                &[Algorithm::Aid, Algorithm::Pr, Algorithm::Tc],
                &Strategy::inventory(),
                &cfg,
            )
            .unwrap();
    }
    store
}

/// One task per (graph, algorithm) — features are strategy-independent.
fn tasks_of(store: &LogStore) -> Vec<TaskFeatures> {
    let mut seen = std::collections::BTreeSet::new();
    let mut out = Vec::new();
    for l in &store.logs {
        if seen.insert((l.graph.clone(), l.algorithm.clone())) {
            out.push(l.features.clone());
        }
    }
    out
}

/// Recompute the checksum footer after tampering with the payload, so
/// only the tampered field — not the checksum — trips the loader.
fn rechecksum(text: &str) -> String {
    let pos = text.rfind("\nchecksum ").unwrap();
    let payload = &text[..pos + 1];
    format!("{payload}checksum {:016x}\n", fnv1a64(payload.as_bytes()))
}

fn assert_roundtrip_bit_identical(etrm: &Etrm, tag: &str, corpus: &LogStore) {
    let dir = scratch(tag);
    let path = dir.join("model.etrm");
    store::save(etrm, &path).unwrap();
    let loaded = store::load(&path).unwrap();
    assert_eq!(loaded.label, etrm.label, "{tag}: label channel survives");
    assert_eq!(loaded.backend.name(), etrm.backend.name());
    for task in tasks_of(corpus) {
        let a = etrm.predict_all(&task);
        let b = loaded.predict_all(&task);
        assert_eq!(a.len(), b.len());
        for ((s1, t1), (s2, t2)) in a.iter().zip(&b) {
            assert_eq!(s1, s2);
            assert_eq!(
                t1.to_bits(),
                t2.to_bits(),
                "{tag}: {} prediction differs after reload",
                s1.name()
            );
        }
        assert_eq!(etrm.select(&task), loaded.select(&task), "{tag}: selection differs");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn gbdt_save_load_predicts_bit_identically() {
    let c = corpus();
    let etrm = Etrm::train_gbdt(
        &c.logs,
        GbdtParams { n_estimators: 40, max_depth: 6, ..GbdtParams::fast() },
        Label::SimTime,
    );
    assert_roundtrip_bit_identical(&etrm, "gbdt", &c);
}

#[test]
fn ridge_save_load_predicts_bit_identically() {
    let c = corpus();
    // the wall-clock channel round-trips through the artifact too
    let etrm = Etrm::train_ridge(&c.logs, 1.0, Label::WallClock);
    assert_roundtrip_bit_identical(&etrm, "ridge", &c);
}

#[test]
fn mlp_save_load_predicts_bit_identically() {
    let c = corpus();
    let etrm = Etrm::train_mlp(
        &c.logs,
        MlpParams { hidden: 16, epochs: 8, ..Default::default() },
        Label::SimTime,
    );
    assert_roundtrip_bit_identical(&etrm, "mlp", &c);
}

#[test]
fn corrupted_and_truncated_artifacts_are_rejected() {
    let c = corpus();
    let etrm = Etrm::train_ridge(&c.logs, 1.0, Label::SimTime);
    let dir = scratch("corrupt");
    let path = dir.join("model.etrm");
    store::save(&etrm, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();

    // truncation: the footer is gone entirely
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    let err = store::load(&path).unwrap_err().to_string();
    assert!(err.contains("checksum") || err.contains("truncated"), "{err}");

    // bit rot: one flipped payload byte fails the checksum
    let mut bytes = text.clone().into_bytes();
    let mid = text.len() / 3;
    bytes[mid] = if bytes[mid] == b'0' { b'1' } else { b'0' };
    std::fs::write(&path, &bytes).unwrap();
    let err = store::load(&path).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");

    // a missing file is a read error naming the path
    let err = store::load(&dir.join("nope.etrm")).unwrap_err().to_string();
    assert!(err.contains("nope.etrm"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn mismatched_manifests_are_rejected() {
    let c = corpus();
    let etrm = Etrm::train_ridge(&c.logs, 1.0, Label::SimTime);
    let dir = scratch("mismatch");
    let path = dir.join("model.etrm");
    store::save(&etrm, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();

    // a model built under a different feature schema must be rejected
    // (re-checksummed, so the *schema* check — not the checksum — fires)
    let tampered = rechecksum(&text.replace(
        &format!("feature-dim {}", gps_select::features::FEATURE_DIM),
        "feature-dim 51",
    ));
    std::fs::write(&path, &tampered).unwrap();
    let err = store::load(&path).unwrap_err().to_string();
    assert!(err.contains("feature dimension"), "{err}");
    assert!(err.contains("retrain"), "{err}");

    // a stale strategy inventory would misalign the one-hot columns
    let tampered = rechecksum(&text.replace("strategies 0:1DSrc", "strategies 0:Legacy"));
    std::fs::write(&path, &tampered).unwrap();
    let err = store::load(&path).unwrap_err().to_string();
    assert!(err.contains("strategy inventory"), "{err}");

    // a stale opkey schema likewise
    let tampered = rechecksum(&text.replace("opkeys NUM_VERTEX", "opkeys OLD_KEY"));
    std::fs::write(&path, &tampered).unwrap();
    let err = store::load(&path).unwrap_err().to_string();
    assert!(err.contains("opkey"), "{err}");

    // an unknown format version is rejected by the header
    let tampered = rechecksum(&text.replace("gps-etrm v1", "gps-etrm v99"));
    std::fs::write(&path, &tampered).unwrap();
    let err = store::load(&path).unwrap_err().to_string();
    assert!(err.contains("v99"), "{err}");

    // label-channel demands: the intact artifact satisfies SimTime,
    // rejects WallClock with a clear error
    std::fs::write(&path, &text).unwrap();
    assert!(store::load_expecting(&path, None).is_ok());
    assert!(store::load_expecting(&path, Some(Label::SimTime)).is_ok());
    let err = store::load_expecting(&path, Some(Label::WallClock)).unwrap_err().to_string();
    assert!(err.contains("label channel"), "{err}");
    assert!(err.contains("wall_clock"), "{err}");

    // the recorded channel is part of the checksummed payload and
    // round-trips: a (re-checksummed) wall_clock artifact loads as such
    let tampered = rechecksum(&text.replace("label sim_time", "label wall_clock"));
    std::fs::write(&path, &tampered).unwrap();
    assert_eq!(store::load(&path).unwrap().label, Label::WallClock);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn select_batch_matches_sequential_across_thread_counts() {
    let c = corpus();
    let etrm = Etrm::train_gbdt(
        &c.logs,
        GbdtParams { n_estimators: 30, max_depth: 5, ..GbdtParams::fast() },
        Label::SimTime,
    );
    let tasks = tasks_of(&c);
    assert!(tasks.len() >= 6, "need a real batch, got {}", tasks.len());
    let sequential: Vec<Strategy> = tasks.iter().map(|t| etrm.select(t)).collect();
    for threads in [1, 2, 4] {
        assert_eq!(
            etrm.select_batch(&tasks, threads),
            sequential,
            "batched selection diverged at {threads} pool threads"
        );
    }
    // and a reloaded artifact serves the identical batch
    let dir = scratch("batch");
    let path = dir.join("model.etrm");
    store::save(&etrm, &path).unwrap();
    let loaded = store::load(&path).unwrap();
    assert_eq!(loaded.select_batch(&tasks, 4), sequential);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A regressor that returns NaN everywhere except (optionally) one
/// strategy one-hot column — the failure injection for the selector.
struct NanAt {
    finite_col: Option<usize>,
}

impl Regressor for NanAt {
    fn predict(&self, x: &[f64]) -> f64 {
        match self.finite_col {
            Some(c) if x[c] == 1.0 => 3.25,
            _ => f64::NAN,
        }
    }
}

/// Regression test for the old `partial_cmp().unwrap()` panic: NaN
/// predictions must never panic nor win, and the all-NaN fallback is
/// deterministic.
#[test]
fn nan_predictions_select_deterministically() {
    let c = corpus();
    let task = c.logs[0].features.clone();
    // all-NaN: fall back to the first inventory strategy
    let all_nan = Etrm {
        backend: EtrmBackend::External(Box::new(NanAt { finite_col: None })),
        label: Label::SimTime,
    };
    assert_eq!(all_nan.select(&task), Strategy::inventory()[0]);
    // the single finite prediction wins over every NaN: column 37 + 5
    // is Hybrid's one-hot slot in the Fig 5 encoding
    let one = Etrm {
        backend: EtrmBackend::External(Box::new(NanAt { finite_col: Some(42) })),
        label: Label::SimTime,
    };
    assert_eq!(one.select(&task), Strategy::Hybrid);
    let batch = vec![task.clone(); 5];
    let picks = one.select_batch(&batch, 2);
    assert!(picks.iter().all(|s| *s == Strategy::Hybrid), "{picks:?}");
}

/// All-equal predictions tie-break to inventory order (deterministic).
struct Constant;

impl Regressor for Constant {
    fn predict(&self, _x: &[f64]) -> f64 {
        1.0
    }
}

#[test]
fn equal_predictions_tie_break_to_inventory_order() {
    let c = corpus();
    let etrm = Etrm {
        backend: EtrmBackend::External(Box::new(Constant)),
        label: Label::SimTime,
    };
    assert_eq!(etrm.select(&c.logs[0].features), Strategy::OneDSrc);
}

#[test]
fn external_backend_cannot_be_saved() {
    let etrm = Etrm {
        backend: EtrmBackend::External(Box::new(Constant)),
        label: Label::SimTime,
    };
    let dir = scratch("external");
    let err = store::save(&etrm, &dir.join("x.etrm")).unwrap_err().to_string();
    assert!(err.contains("External"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}
