//! Wire-format property test: `Envelope → bytes → Envelope` round-trips
//! **bit-exactly** for every `Msg` variant, over randomized payloads and
//! the real payload type shapes of the algorithm inventory (scalar f64,
//! i64 values, and the `(Vec<u32>, f64)` neighbour-list accumulators of
//! GC/TC/CC/APCN). Digests are compared through `Payload::fold_bits`,
//! the same bit-exactness notion the mode-equivalence guarantee is
//! stated over.

use gps_select::algorithms::coloring::GreedyColoring;
use gps_select::algorithms::pagerank::PageRank;
use gps_select::algorithms::triangle::TriangleCount;
use gps_select::engine::gas::{Payload, VertexProgram};
use gps_select::engine::msg::{Envelope, Msg, PhaseStats, SendAccount};
use gps_select::engine::wire;
use gps_select::util::rng::{Rng, FNV1A64_OFFSET};

/// Round-trip one envelope and return the decoded copy, asserting the
/// encoding consumed exactly and the addressing survived.
fn roundtrip<P: VertexProgram>(e: &Envelope<P>) -> Envelope<P> {
    let mut buf = Vec::new();
    wire::encode_envelope(e, &mut buf);
    let mut r = wire::Reader::new(&buf);
    let got = wire::decode_envelope::<P>(&mut r).expect("decode");
    r.finish().expect("no trailing bytes");
    assert_eq!(got.from, e.from);
    assert_eq!(got.to, e.to);
    got
}

fn digest<P: VertexProgram>(m: &Msg<P>) -> u64 {
    match m {
        Msg::GatherPartial { v, partial } => partial.fold_bits(v.fold_bits(FNV1A64_OFFSET)),
        Msg::ValueUpdate { v, value } => value.fold_bits(v.fold_bits(FNV1A64_OFFSET)),
        Msg::ResultEmit { bytes } => (*bytes as u64 as f64).fold_bits(FNV1A64_OFFSET),
        Msg::Activate { v } => v.fold_bits(FNV1A64_OFFSET),
    }
}

fn assert_bits_survive<P: VertexProgram>(e: &Envelope<P>) {
    let got = roundtrip(e);
    assert_eq!(std::mem::discriminant(&got.msg), std::mem::discriminant(&e.msg));
    assert_eq!(digest(&got.msg), digest(&e.msg), "payload bits must survive the wire");
}

/// Adversarial f64 bit patterns the textual formats would mangle.
fn nasty_f64(rng: &mut Rng, i: usize) -> f64 {
    match i % 6 {
        0 => -0.0,
        1 => f64::MIN_POSITIVE / 2.0, // subnormal
        2 => f64::INFINITY,
        3 => f64::from_bits(0x7ff8_0000_0000_1234), // NaN with payload bits
        4 => rng.next_f64() * 1e300,
        _ => -rng.next_f64() / 1e300,
    }
}

/// Scalar-f64 programs (PR/AID/AOD/RW shape): every variant, random and
/// adversarial payload bits.
#[test]
fn envelope_roundtrip_scalar_f64_program() {
    let mut rng = Rng::new(0x51f7);
    for i in 0..500 {
        let from = rng.gen_range(64) as u16;
        let to = rng.gen_range(64) as u16;
        let v = rng.gen_range(100_000) as u32;
        let x = nasty_f64(&mut rng, i);
        let cases: Vec<Envelope<PageRank>> = vec![
            Envelope { from, to, msg: Msg::GatherPartial { v, partial: x } },
            Envelope { from, to, msg: Msg::ValueUpdate { v, value: x } },
            Envelope { from, to, msg: Msg::ResultEmit { bytes: rng.gen_range(1 << 20) } },
            Envelope { from, to, msg: Msg::Activate { v } },
        ];
        for e in &cases {
            assert_bits_survive(e);
        }
    }
}

/// Neighbour-list programs (TC/CC/APCN shape): `(Vec<u32>, f64)` values
/// and accumulators of random lengths, including empty.
#[test]
fn envelope_roundtrip_list_program() {
    let mut rng = Rng::new(0x7c11);
    for i in 0..300 {
        let len = rng.gen_range(40);
        let list: Vec<u32> = (0..len).map(|_| rng.gen_range(1 << 24) as u32).collect();
        let pair = (list, nasty_f64(&mut rng, i));
        let e: Envelope<TriangleCount> = Envelope {
            from: rng.gen_range(16) as u16,
            to: rng.gen_range(16) as u16,
            msg: Msg::GatherPartial { v: rng.gen_range(5000) as u32, partial: pair.clone() },
        };
        assert_bits_survive(&e);
        let e: Envelope<TriangleCount> = Envelope {
            from: 1,
            to: 2,
            msg: Msg::ValueUpdate { v: 9, value: pair },
        };
        assert_bits_survive(&e);
    }
}

/// Mixed-type program (GC: i64 values, list accumulators) — the variant
/// matrix again under a third type shape, plus negative i64 values.
#[test]
fn envelope_roundtrip_mixed_program() {
    let mut rng = Rng::new(0x6c0c);
    for _ in 0..300 {
        let value = (rng.next_u64() as i64).wrapping_sub(i64::MAX / 2);
        let e: Envelope<GreedyColoring> =
            Envelope { from: 0, to: 1, msg: Msg::ValueUpdate { v: 3, value } };
        assert_bits_survive(&e);
        let acc = ((0..rng.gen_range(10)).map(|_| rng.gen_range(999) as u32).collect(), -1.5);
        let e: Envelope<GreedyColoring> =
            Envelope { from: 3, to: 0, msg: Msg::GatherPartial { v: 8, partial: acc } };
        assert_bits_survive(&e);
    }
}

fn assert_same_envelopes<P: VertexProgram>(got: &[Envelope<P>], want: &[Envelope<P>]) {
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.from, w.from);
        assert_eq!(g.to, w.to);
        assert_eq!(std::mem::discriminant(&g.msg), std::mem::discriminant(&w.msg));
        assert_eq!(digest(&g.msg), digest(&w.msg), "payload bits must survive the wire");
    }
}

/// The coalesced inbox frame: multiple senders, shared-kind runs,
/// non-monotonic vertex ids (negative deltas), id 0, ids near
/// `u32::MAX`, and adversarial f64 bits (NaN payload, subnormal, -0.0).
#[test]
fn batched_inbox_roundtrip_multi_sender_runs() {
    let to = 3u16;
    let env: Vec<Envelope<PageRank>> = vec![
        // sender 0: a gather run with descending then ascending ids
        Envelope { from: 0, to, msg: Msg::GatherPartial { v: 500, partial: -0.0 } },
        Envelope { from: 0, to, msg: Msg::GatherPartial { v: 2, partial: f64::MIN_POSITIVE / 2.0 } },
        Envelope {
            from: 0,
            to,
            msg: Msg::GatherPartial {
                v: u32::MAX,
                partial: f64::from_bits(0x7ff8_0000_0000_1234),
            },
        },
        // same sender, kind switch mid-stream: run must break
        Envelope { from: 0, to, msg: Msg::Activate { v: 0 } },
        Envelope { from: 0, to, msg: Msg::GatherPartial { v: 7, partial: f64::INFINITY } },
        // sender 2: value updates then a result emission
        Envelope { from: 2, to, msg: Msg::ValueUpdate { v: 0, value: -0.0 } },
        Envelope { from: 2, to, msg: Msg::ValueUpdate { v: u32::MAX - 1, value: 1.0e-300 } },
        Envelope { from: 2, to, msg: Msg::ResultEmit { bytes: usize::MAX >> 16 } },
        // sender 5: a lone activation
        Envelope { from: 5, to, msg: Msg::Activate { v: 41 } },
    ];
    let payload = wire::encode_inbox(&env, to);
    let got = wire::decode_inbox::<PageRank>(&payload).expect("decode batched inbox");
    assert_same_envelopes(&got, &env);
}

/// The coalesced phase-output frame: stats bits plus per-destination
/// sections (empty destinations skipped) must survive, and destination
/// bounds are enforced against the decoder's worker count.
#[test]
fn batched_phase_out_roundtrip() {
    let stats = PhaseStats {
        compute: 0.1 + 0.2, // a value with an inexact representation
        gathers: 7,
        applies: 6,
        scatters: 5,
        send: SendAccount { msgs: 4, bytes: 999, intra: -0.0, inter: 1.0e-300 },
    };
    let mk = |to: u16, v: u32, list: Vec<u32>| Envelope::<TriangleCount> {
        from: 1,
        to,
        msg: Msg::GatherPartial { v, partial: (list, -0.0) },
    };
    let batches: Vec<Vec<Envelope<TriangleCount>>> = vec![
        vec![mk(0, 9, vec![3, 1, 4]), mk(0, 4, vec![])],
        Vec::new(), // destination 1 gets nothing: no section on the wire
        vec![mk(2, 0, vec![u32::MAX])],
        Vec::new(),
    ];
    let payload = wire::encode_phase_out(&stats, &batches);
    let (got_stats, got) =
        wire::decode_phase_out::<TriangleCount>(&payload, 4).expect("decode batched phase out");
    assert_eq!(got_stats.compute.to_bits(), stats.compute.to_bits());
    assert_eq!(got_stats.send.bytes, stats.send.bytes);
    assert_eq!(got_stats.send.intra.to_bits(), stats.send.intra.to_bits());
    assert_eq!(got.len(), 2, "only non-empty destinations travel");
    assert_eq!(got[0].0, 0);
    assert_eq!(got[1].0, 2);
    assert_same_envelopes(&got[0].1, &batches[0]);
    assert_same_envelopes(&got[1].1, &batches[2]);

    // a decoder sized for fewer workers must reject section 2
    assert!(wire::decode_phase_out::<TriangleCount>(&payload, 2).is_err());
}

/// Hand-built section order violation: destinations on the wire must be
/// strictly ascending, or a relay could deliver sender-unsorted inboxes.
#[test]
fn phase_out_rejects_unsorted_destinations() {
    let stats = PhaseStats::default();
    let mut payload = Vec::new();
    wire::encode_stats(&stats, &mut payload);
    wire::put_u16(&mut payload, 2); // two sections
    for to in [2u16, 1u16] {
        wire::put_u16(&mut payload, to);
        let env: Vec<Envelope<PageRank>> =
            vec![Envelope { from: 0, to, msg: Msg::Activate { v: 1 } }];
        wire::encode_envelope_seq(&env, &mut payload);
    }
    let err = wire::decode_phase_out::<PageRank>(&payload, 4).unwrap_err().to_string();
    assert!(err.contains("ascending"), "{err}");
}

/// Truncating a batched frame anywhere must produce a decode error,
/// never a panic or a silently short inbox.
#[test]
fn truncated_batched_frames_error_cleanly() {
    let to = 1u16;
    let env: Vec<Envelope<TriangleCount>> = vec![
        Envelope { from: 0, to, msg: Msg::GatherPartial { v: 5, partial: (vec![1, 2, 3], 0.25) } },
        Envelope { from: 0, to, msg: Msg::GatherPartial { v: 3, partial: (vec![], -0.0) } },
        Envelope { from: 2, to, msg: Msg::ResultEmit { bytes: 1 << 30 } },
    ];
    let payload = wire::encode_inbox(&env, to);
    for cut in 0..payload.len() {
        assert!(
            wire::decode_inbox::<TriangleCount>(&payload[..cut]).is_err(),
            "decode of a {cut}-byte prefix must fail"
        );
    }
}

/// Truncating an encoded envelope anywhere must produce a decode error,
/// never a panic or a silently short value.
#[test]
fn truncated_envelopes_error_cleanly() {
    let e: Envelope<TriangleCount> = Envelope {
        from: 1,
        to: 2,
        msg: Msg::GatherPartial { v: 5, partial: (vec![1, 2, 3, 4], 0.25) },
    };
    let mut buf = Vec::new();
    wire::encode_envelope(&e, &mut buf);
    for cut in 0..buf.len() {
        let mut r = wire::Reader::new(&buf[..cut]);
        assert!(
            wire::decode_envelope::<TriangleCount>(&mut r).is_err(),
            "decode of a {cut}-byte prefix must fail"
        );
    }
}
