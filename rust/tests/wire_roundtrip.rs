//! Wire-format property test: `Envelope → bytes → Envelope` round-trips
//! **bit-exactly** for every `Msg` variant, over randomized payloads and
//! the real payload type shapes of the algorithm inventory (scalar f64,
//! i64 values, and the `(Vec<u32>, f64)` neighbour-list accumulators of
//! GC/TC/CC/APCN). Digests are compared through `Payload::fold_bits`,
//! the same bit-exactness notion the mode-equivalence guarantee is
//! stated over.

use gps_select::algorithms::coloring::GreedyColoring;
use gps_select::algorithms::pagerank::PageRank;
use gps_select::algorithms::triangle::TriangleCount;
use gps_select::engine::gas::{Payload, VertexProgram};
use gps_select::engine::msg::{Envelope, Msg};
use gps_select::engine::wire;
use gps_select::util::rng::{Rng, FNV1A64_OFFSET};

/// Round-trip one envelope and return the decoded copy, asserting the
/// encoding consumed exactly and the addressing survived.
fn roundtrip<P: VertexProgram>(e: &Envelope<P>) -> Envelope<P> {
    let mut buf = Vec::new();
    wire::encode_envelope(e, &mut buf);
    let mut r = wire::Reader::new(&buf);
    let got = wire::decode_envelope::<P>(&mut r).expect("decode");
    r.finish().expect("no trailing bytes");
    assert_eq!(got.from, e.from);
    assert_eq!(got.to, e.to);
    got
}

fn digest<P: VertexProgram>(m: &Msg<P>) -> u64 {
    match m {
        Msg::GatherPartial { v, partial } => partial.fold_bits(v.fold_bits(FNV1A64_OFFSET)),
        Msg::ValueUpdate { v, value } => value.fold_bits(v.fold_bits(FNV1A64_OFFSET)),
        Msg::ResultEmit { bytes } => (*bytes as u64 as f64).fold_bits(FNV1A64_OFFSET),
        Msg::Activate { v } => v.fold_bits(FNV1A64_OFFSET),
    }
}

fn assert_bits_survive<P: VertexProgram>(e: &Envelope<P>) {
    let got = roundtrip(e);
    assert_eq!(std::mem::discriminant(&got.msg), std::mem::discriminant(&e.msg));
    assert_eq!(digest(&got.msg), digest(&e.msg), "payload bits must survive the wire");
}

/// Adversarial f64 bit patterns the textual formats would mangle.
fn nasty_f64(rng: &mut Rng, i: usize) -> f64 {
    match i % 6 {
        0 => -0.0,
        1 => f64::MIN_POSITIVE / 2.0, // subnormal
        2 => f64::INFINITY,
        3 => f64::from_bits(0x7ff8_0000_0000_1234), // NaN with payload bits
        4 => rng.next_f64() * 1e300,
        _ => -rng.next_f64() / 1e300,
    }
}

/// Scalar-f64 programs (PR/AID/AOD/RW shape): every variant, random and
/// adversarial payload bits.
#[test]
fn envelope_roundtrip_scalar_f64_program() {
    let mut rng = Rng::new(0x51f7);
    for i in 0..500 {
        let from = rng.gen_range(64) as u16;
        let to = rng.gen_range(64) as u16;
        let v = rng.gen_range(100_000) as u32;
        let x = nasty_f64(&mut rng, i);
        let cases: Vec<Envelope<PageRank>> = vec![
            Envelope { from, to, msg: Msg::GatherPartial { v, partial: x } },
            Envelope { from, to, msg: Msg::ValueUpdate { v, value: x } },
            Envelope { from, to, msg: Msg::ResultEmit { bytes: rng.gen_range(1 << 20) } },
            Envelope { from, to, msg: Msg::Activate { v } },
        ];
        for e in &cases {
            assert_bits_survive(e);
        }
    }
}

/// Neighbour-list programs (TC/CC/APCN shape): `(Vec<u32>, f64)` values
/// and accumulators of random lengths, including empty.
#[test]
fn envelope_roundtrip_list_program() {
    let mut rng = Rng::new(0x7c11);
    for i in 0..300 {
        let len = rng.gen_range(40);
        let list: Vec<u32> = (0..len).map(|_| rng.gen_range(1 << 24) as u32).collect();
        let pair = (list, nasty_f64(&mut rng, i));
        let e: Envelope<TriangleCount> = Envelope {
            from: rng.gen_range(16) as u16,
            to: rng.gen_range(16) as u16,
            msg: Msg::GatherPartial { v: rng.gen_range(5000) as u32, partial: pair.clone() },
        };
        assert_bits_survive(&e);
        let e: Envelope<TriangleCount> = Envelope {
            from: 1,
            to: 2,
            msg: Msg::ValueUpdate { v: 9, value: pair },
        };
        assert_bits_survive(&e);
    }
}

/// Mixed-type program (GC: i64 values, list accumulators) — the variant
/// matrix again under a third type shape, plus negative i64 values.
#[test]
fn envelope_roundtrip_mixed_program() {
    let mut rng = Rng::new(0x6c0c);
    for _ in 0..300 {
        let value = (rng.next_u64() as i64).wrapping_sub(i64::MAX / 2);
        let e: Envelope<GreedyColoring> =
            Envelope { from: 0, to: 1, msg: Msg::ValueUpdate { v: 3, value } };
        assert_bits_survive(&e);
        let acc = ((0..rng.gen_range(10)).map(|_| rng.gen_range(999) as u32).collect(), -1.5);
        let e: Envelope<GreedyColoring> =
            Envelope { from: 3, to: 0, msg: Msg::GatherPartial { v: 8, partial: acc } };
        assert_bits_survive(&e);
    }
}

/// Truncating an encoded envelope anywhere must produce a decode error,
/// never a panic or a silently short value.
#[test]
fn truncated_envelopes_error_cleanly() {
    let e: Envelope<TriangleCount> = Envelope {
        from: 1,
        to: 2,
        msg: Msg::GatherPartial { v: 5, partial: (vec![1, 2, 3, 4], 0.25) },
    };
    let mut buf = Vec::new();
    wire::encode_envelope(&e, &mut buf);
    for cut in 0..buf.len() {
        let mut r = wire::Reader::new(&buf[..cut]);
        assert!(
            wire::decode_envelope::<TriangleCount>(&mut r).is_err(),
            "decode of a {cut}-byte prefix must fail"
        );
    }
}
