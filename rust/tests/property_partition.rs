//! Property-based tests over the partitioning strategies (the
//! coordinator's core invariants), using seeded random graph generation
//! as the input sweep (an offline stand-in for proptest).
//!
//! For every strategy × random graph × worker count:
//! 1. every edge is assigned exactly once, to a valid worker;
//! 2. the replica sets cover exactly the workers with incident edges;
//! 3. the master of every non-isolated vertex is one of its replicas;
//! 4. replication factor ≥ 1 and ≤ min(|W|, max degree bound);
//! 5. determinism: identical inputs → identical assignments.

use gps_select::graph::gen::{chung_lu, erdos, grid, smallworld};
use gps_select::graph::Graph;
use gps_select::partition::metrics::PartitionMetrics;
use gps_select::partition::Strategy;
use gps_select::util::rng::Rng;

fn random_graph(case: u64) -> Graph {
    let mut rng = Rng::new(0xbeef ^ case);
    let n = 50 + rng.gen_range(400);
    let density = 2 + rng.gen_range(6);
    let m = (n * density).min(n * (n - 1) / 4);
    match case % 4 {
        0 => erdos::generate("er", n, m, rng.gen_bool(0.5), &mut rng),
        1 => chung_lu::generate("cl", n, m, 2.05 + rng.next_f64(), rng.gen_bool(0.5), &mut rng),
        2 => smallworld::generate("sw", n, m.max(n), 0.1, &mut rng),
        _ => grid::generate("gr", n, (n * 14 / 10).min(m.max(n)), &mut rng),
    }
}

#[test]
fn partition_invariants_hold_over_random_inputs() {
    for case in 0..24u64 {
        let g = random_graph(case);
        let workers = [1usize, 2, 7, 16, 64][(case % 5) as usize];
        for s in Strategy::all() {
            let p = s.partition(&g, workers);
            // (1) complete assignment
            assert_eq!(p.edge_worker.len(), g.num_edges(), "{case}/{}", s.name());
            assert!(p.edge_worker.iter().all(|&w| (w as usize) < workers));
            assert_eq!(
                p.edges_per_worker.iter().sum::<usize>(),
                g.num_edges(),
                "{case}/{}",
                s.name()
            );
            // (2) replica sets match incident edges
            let mut expected: Vec<std::collections::BTreeSet<u16>> =
                vec![Default::default(); g.num_vertices()];
            for (e, &(u, v)) in g.edges().iter().enumerate() {
                expected[u as usize].insert(p.edge_worker[e]);
                expected[v as usize].insert(p.edge_worker[e]);
            }
            for v in g.vertices() {
                let got: std::collections::BTreeSet<u16> =
                    p.replicas[v as usize].iter().copied().collect();
                assert_eq!(got, expected[v as usize], "{case}/{} vertex {v}", s.name());
                // (3) master membership
                if !got.is_empty() {
                    assert!(
                        got.contains(&p.master[v as usize]),
                        "{case}/{} vertex {v} master outside replicas",
                        s.name()
                    );
                }
            }
            // (4) replication factor bounds: every non-isolated vertex
            // has ≥1 replica (isolated ones have none, so rf can dip
            // below 1 on graphs with isolated vertices)
            let non_isolated =
                g.vertices().filter(|&v| g.degree(v) > 0).count() as f64;
            let m = PartitionMetrics::of(&g, &p);
            assert!(
                m.replication_factor >= non_isolated / g.num_vertices() as f64 - 1e-9,
                "{case}/{}",
                s.name()
            );
            assert!(
                m.replication_factor <= workers as f64 + 1e-9,
                "{case}/{}: rf {}",
                s.name(),
                m.replication_factor
            );
            // (5) determinism
            let again = s.partition(&g, workers);
            assert_eq!(p.edge_worker, again.edge_worker, "{case}/{}", s.name());
        }
    }
}

/// The 2D strategy's replication bound (2√|W| for square grids) must
/// hold on every random input — it is a *guarantee*, not a tendency.
#[test]
fn twod_replication_bound_is_hard() {
    for case in 0..12u64 {
        let g = random_graph(case);
        for &w in &[4usize, 16, 64] {
            let p = Strategy::TwoD.partition(&g, w);
            let bound = 2 * (w as f64).sqrt() as usize;
            for v in g.vertices() {
                assert!(
                    p.replicas[v as usize].len() <= bound,
                    "case {case}, w {w}, vertex {v}: {} > {bound}",
                    p.replicas[v as usize].len()
                );
            }
        }
    }
}

/// Degree-ordered invariant for HDRF: with λ → large, edge balance must
/// approach perfection on every input.
#[test]
fn hdrf_high_lambda_always_balances() {
    for case in 0..8u64 {
        let g = random_graph(case);
        let p = Strategy::Hdrf(100).partition(&g, 8);
        let m = PartitionMetrics::of(&g, &p);
        assert!(m.edge_balance < 1.35, "case {case}: {}", m.edge_balance);
    }
}
