//! Selection-daemon gates (ISSUE 8 acceptance): answers served over
//! TCP are bit-identical to offline `repro select` (cross-process);
//! N concurrent clients with mixed single/batched requests match
//! sequential selection bit-for-bit; a hot artifact swap changes
//! answers only at a request boundary; a corrupt swap is rejected
//! while the loaded model keeps serving; malformed frames and
//! mid-request disconnects never take the daemon down; and shutdown
//! drains in-flight requests before the listener closes.

use std::io::{BufRead, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::Duration;

use gps_select::engine::wire;
use gps_select::etrm::{store, Etrm, EtrmBackend};
use gps_select::features::{zeroed_task, TaskFeatures, FEATURE_DIM};
use gps_select::ml::linear::Ridge;
use gps_select::ml::Label;
use gps_select::partition::Strategy;
use gps_select::service::app::{self, ModelHandle};
use gps_select::service::proto::{self, Client, ReloadStatus};
use gps_select::service::serve::{ServeConfig, Server};
use gps_select::util::rng::Rng;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gps_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A ridge model whose lone negative weight sits on `favorite`'s
/// one-hot column — `select` deterministically picks
/// `Strategy::INVENTORY[favorite]`, making hot swaps observable.
fn favoring_etrm(favorite: usize) -> Etrm {
    let mut weights = vec![0.0f64; FEATURE_DIM + 1];
    // one-hot block sits before the 4 family columns and the trailing
    // cluster block
    let onehot_base = FEATURE_DIM
        - gps_select::engine::cluster::CLUSTER_FEATURE_DIM
        - 4
        - Strategy::INVENTORY.len();
    weights[onehot_base + favorite] = -1.0;
    Etrm {
        backend: EtrmBackend::Ridge(Ridge { weights, log_target: false }),
        label: Label::SimTime,
    }
}

/// A ridge model with dense pseudo-random weights: picks genuinely
/// depend on the task features, so equivalence tests are meaningful.
fn varied_etrm(seed: u64) -> Etrm {
    let mut rng = Rng::new(seed);
    let weights = (0..=FEATURE_DIM).map(|_| rng.next_f64() - 0.5).collect();
    Etrm {
        backend: EtrmBackend::Ridge(Ridge { weights, log_target: false }),
        label: Label::SimTime,
    }
}

/// Deterministic synthetic tasks spanning degree shapes.
fn synthetic_tasks(n: usize, seed: u64) -> Vec<TaskFeatures> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut t = zeroed_task();
            t.data.num_vertices = (1.0e3 + rng.next_f64() * 1.0e6).floor();
            t.data.num_edges = (t.data.num_vertices * (1.0 + rng.next_f64() * 40.0)).floor();
            t.data.directed = rng.next_f64() < 0.5;
            t.data.in_deg.mean = rng.next_f64() * 30.0;
            t.data.in_deg.std = rng.next_f64() * 80.0;
            t.data.in_deg.skewness = rng.next_f64() * 8.0 - 2.0;
            t.data.in_deg.kurtosis = rng.next_f64() * 40.0 - 3.0;
            t.data.out_deg = t.data.in_deg;
            for a in t.algo.iter_mut() {
                *a = (rng.next_f64() * 1.0e5).floor();
            }
            t
        })
        .collect()
}

/// In-process daemon over a freshly saved artifact. Poller disabled:
/// the tests drive reloads explicitly for determinism.
fn start_server(model_path: &Path, threads: usize) -> (Server, String) {
    let handle = ModelHandle::open(model_path, None).unwrap();
    let cfg = ServeConfig {
        listen: "127.0.0.1:0".to_string(),
        threads,
        reload_poll_ms: 0,
        max_coalesce: 64,
    };
    let server = Server::start(cfg, handle).unwrap();
    let addr = server.local_addr().to_string();
    (server, addr)
}

fn client(addr: &str) -> Client {
    let c = Client::connect(addr).unwrap();
    c.set_timeout(Duration::from_secs(30)).unwrap();
    c
}

/// The tentpole gate, cross-process: a real `repro serve` child must
/// answer with exactly the prediction bits that a separate `repro
/// select --bits-out` process computes offline for the same artifact
/// and tasks.
#[test]
fn daemon_bits_match_offline_select_cross_process() {
    let dir = scratch("offline");
    let model = dir.join("model.etrm");
    store::save(&varied_etrm(0xd00d), &model).unwrap();

    // offline half: a child process renders the probe bits to a file
    let bits_path = dir.join("offline.bits");
    let status = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["select", "--model"])
        .arg(&model)
        .args(["--graph", "wiki", "--algorithm", "PR,TC", "--scale", "0.01", "--seed", "7"])
        .args(["--threads", "2", "--bits-out"])
        .arg(&bits_path)
        .stdout(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "offline select failed");
    let offline = std::fs::read_to_string(&bits_path).unwrap();

    // serving half: a daemon child answers the same tasks over TCP
    let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["serve", "--model"])
        .arg(&model)
        .args(["--listen", "127.0.0.1:0", "--reload-poll-ms", "0"])
        .stdout(Stdio::piped())
        .spawn()
        .unwrap();
    let mut banner = std::io::BufReader::new(child.stdout.take().unwrap());
    let mut addr = String::new();
    let mut line = String::new();
    while addr.is_empty() {
        line.clear();
        assert!(banner.read_line(&mut line).unwrap() > 0, "daemon died during startup");
        if let Some(rest) = line.trim_end().strip_prefix("serve: listening on ") {
            addr = rest.to_string();
        }
    }

    // the same features the offline process extracted, re-extracted
    // here (deterministic generators: same scale + seed → same graph)
    let g = app::GraphSpec { name: "wiki".to_string(), scale: 0.01, seed: 7 }.build().unwrap();
    let (algos, tasks) = app::algorithm_tasks(&g, &["PR", "TC"]).unwrap();
    let names: Vec<String> = algos.iter().map(|a| a.name().to_string()).collect();

    let mut c = client(&addr);
    let reply = c.select(&tasks, true).unwrap();
    let served = reply.render_bits(&g.name, &names).unwrap();
    assert_eq!(served, offline, "served bits differ from offline select");

    let answered = c.shutdown().unwrap();
    assert_eq!(answered, 1);
    let mut rest = String::new();
    banner.read_to_string(&mut rest).unwrap();
    assert!(rest.contains("drained and stopped"), "missing shutdown banner: {rest:?}");
    assert!(child.wait().unwrap().success());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite gate: N parallel clients issuing mixed single/batched
/// requests get exactly the answers sequential selection computes.
#[test]
fn concurrent_mixed_requests_match_sequential_bit_for_bit() {
    let dir = scratch("concurrent");
    let model = dir.join("model.etrm");
    store::save(&varied_etrm(0xfeed), &model).unwrap();
    let reference = store::load(&model).unwrap();
    let (server, addr) = start_server(&model, 2);

    let pool = synthetic_tasks(24, 0xabc);
    let clients = 8usize;
    let requests_per_client = 12usize;
    std::thread::scope(|scope| {
        for c in 0..clients {
            let addr = &addr;
            let pool = &pool;
            let reference = &reference;
            scope.spawn(move || {
                let mut cl = client(addr);
                for r in 0..requests_per_client {
                    let batch = 1 + (c * 5 + r) % 5;
                    let lo = (c * 7 + r * 3) % (pool.len() - batch);
                    let req = &pool[lo..lo + batch];
                    let want_bits = r % 3 == 0;
                    let reply = cl.select(req, want_bits).unwrap();
                    for (i, task) in req.iter().enumerate() {
                        assert_eq!(
                            reply.picks[i],
                            reference.select(task),
                            "client {c} request {r} task {i} diverged from sequential select"
                        );
                        if let Some(tables) = &reply.predictions {
                            let local = reference.predict_all(task);
                            for (j, (_, t)) in local.iter().enumerate() {
                                assert_eq!(
                                    tables[i][j].to_bits(),
                                    t.to_bits(),
                                    "prediction bits diverged"
                                );
                            }
                        }
                    }
                }
            });
        }
    });

    let total = (clients * requests_per_client) as u64;
    let served = client(&addr).shutdown().unwrap();
    assert_eq!(served, total);
    let summary = server.join().unwrap();
    assert_eq!(summary.requests, total);
    assert!(summary.batches >= 1 && summary.batches <= summary.requests);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite gate: a hot artifact swap flips every answer at a request
/// boundary — same connection, no restart, fingerprints consistent.
#[test]
fn hot_reload_changes_answers_at_request_boundary() {
    let dir = scratch("reload");
    let model = dir.join("model.etrm");
    store::save(&favoring_etrm(2), &model).unwrap();
    let (server, addr) = start_server(&model, 1);
    let tasks = synthetic_tasks(3, 1);

    let mut c = client(&addr);
    let before = c.select(&tasks, false).unwrap();
    assert!(before.picks.iter().all(|&s| s == Strategy::INVENTORY[2]), "{:?}", before.picks);

    // same artifact: an explicit reload probe is a no-op
    let noop = c.reload().unwrap();
    assert_eq!(noop.status, ReloadStatus::Unchanged);
    assert_eq!(noop.fingerprint, before.fingerprint);

    // atomically swap the artifact, then reload on the live connection
    store::save(&favoring_etrm(5), &model).unwrap();
    let swapped = c.reload().unwrap();
    assert_eq!(swapped.status, ReloadStatus::Reloaded);
    assert_ne!(swapped.fingerprint, before.fingerprint);
    assert!(swapped.message.contains("->"), "{}", swapped.message);

    let after = c.select(&tasks, false).unwrap();
    assert!(after.picks.iter().all(|&s| s == Strategy::INVENTORY[5]), "{:?}", after.picks);
    assert_eq!(after.fingerprint, swapped.fingerprint);

    client(&addr).shutdown().unwrap();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite gate: a corrupt replacement artifact is rejected without
/// dropping the currently served model — zero downtime, then a later
/// valid swap still goes through.
#[test]
fn corrupt_swap_is_rejected_and_old_model_keeps_serving() {
    let dir = scratch("corrupt");
    let model = dir.join("model.etrm");
    store::save(&favoring_etrm(1), &model).unwrap();
    let (server, addr) = start_server(&model, 1);
    let tasks = synthetic_tasks(2, 2);

    let mut c = client(&addr);
    let before = c.select(&tasks, false).unwrap();
    assert!(before.picks.iter().all(|&s| s == Strategy::INVENTORY[1]));

    // clobber the artifact with garbage that still changes the
    // fingerprint — the reload must fail *after* probing, and keep
    // the loaded model
    gps_select::util::fsio::write_atomic(&model, b"gps-etrm v1\ngarbage payload\n").unwrap();
    let rejected = c.reload().unwrap();
    assert_eq!(rejected.status, ReloadStatus::Rejected);
    assert!(!rejected.message.is_empty());
    assert_eq!(rejected.fingerprint, before.fingerprint, "served model must not change");

    let still = c.select(&tasks, false).unwrap();
    assert_eq!(still.fingerprint, before.fingerprint);
    assert!(still.picks.iter().all(|&s| s == Strategy::INVENTORY[1]));

    // recovery: a valid artifact swaps in on the same connection
    store::save(&favoring_etrm(7), &model).unwrap();
    assert_eq!(c.reload().unwrap().status, ReloadStatus::Reloaded);
    let after = c.select(&tasks, false).unwrap();
    assert!(after.picks.iter().all(|&s| s == Strategy::INVENTORY[7]));

    client(&addr).shutdown().unwrap();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite gate: malformed frames and mid-request disconnects cost
/// at most their own connection — the daemon never panics and keeps
/// serving well-behaved clients.
#[test]
fn malformed_frames_and_disconnects_never_take_the_daemon_down() {
    let dir = scratch("malformed");
    let model = dir.join("model.etrm");
    store::save(&varied_etrm(0xbad), &model).unwrap();
    let (server, addr) = start_server(&model, 1);
    let tasks = synthetic_tasks(2, 3);

    // (a) raw garbage (an impossible frame length): connection dropped
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"\xff\xff\xff\xffgarbage").unwrap();
        let mut buf = [0u8; 16];
        let n = s.read(&mut buf).unwrap_or(0); // EOF or reset, never a reply
        assert_eq!(n, 0, "daemon must drop an unframeable connection");
    }

    // (b) a well-shaped frame with a corrupted checksum: dropped too
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let payload = proto::encode_select_request(&tasks[..1], false);
        let mut frame = Vec::new();
        wire::put_u32(&mut frame, (1 + payload.len() + 8) as u32);
        frame.push(proto::FRAME_SELECT);
        frame.extend_from_slice(&payload);
        wire::put_u64(&mut frame, 0xdead_beef); // wrong checksum
        s.write_all(&frame).unwrap();
        let mut buf = [0u8; 16];
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "daemon must drop a checksum-failing connection");
    }

    // (c) an unknown frame kind: error reply, connection survives
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        wire::write_frame(&mut s, 0x7e, &[]).unwrap();
        let (kind, payload) = wire::read_frame(&mut s).unwrap();
        assert_eq!(kind, proto::FRAME_ERR);
        assert!(proto::decode_err(&payload).contains("unknown service frame kind"));
        // …and the same connection still answers a real request
        wire::write_frame(&mut s, proto::FRAME_PING, &[]).unwrap();
        assert_eq!(wire::read_frame(&mut s).unwrap().0, proto::FRAME_PONG);
    }

    // (d) well-framed but malformed select payload: error reply, then
    // a valid select succeeds on the same connection
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let full = proto::encode_select_request(&tasks[..1], false);
        wire::write_frame(&mut s, proto::FRAME_SELECT, &full[..full.len() / 2]).unwrap();
        let (kind, payload) = wire::read_frame(&mut s).unwrap();
        assert_eq!(kind, proto::FRAME_ERR);
        assert!(!proto::decode_err(&payload).is_empty());
        wire::write_frame(&mut s, proto::FRAME_SELECT, &full).unwrap();
        let (kind, payload) = wire::read_frame(&mut s).unwrap();
        assert_eq!(kind, proto::FRAME_SELECT_OK);
        assert_eq!(proto::decode_select_reply(&payload).unwrap().picks.len(), 1);
    }

    // (e) disconnect right after sending a request: the daemon must
    // absorb the abandoned reply
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let payload = proto::encode_select_request(&tasks, false);
        wire::write_frame(&mut s, proto::FRAME_SELECT, &payload).unwrap();
        drop(s);
    }

    // the daemon is still fully alive for a well-behaved client
    let mut c = client(&addr);
    assert_eq!(c.select(&tasks, false).unwrap().picks.len(), tasks.len());
    c.shutdown().unwrap();
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Satellite gate: shutdown drains in-flight selects, reports the
/// lifetime counters, and closes the listener.
#[test]
fn shutdown_drains_in_flight_requests_then_closes() {
    let dir = scratch("shutdown");
    let model = dir.join("model.etrm");
    store::save(&varied_etrm(0x5151), &model).unwrap();
    let (server, addr) = start_server(&model, 2);
    let tasks = synthetic_tasks(8, 4);

    let successes: u64 = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..4)
            .map(|c| {
                let addr = &addr;
                let tasks = &tasks;
                scope.spawn(move || {
                    let mut cl = client(addr);
                    let mut ok = 0u64;
                    for r in 0..30 {
                        let batch = 1 + (c + r) % 4;
                        match cl.select(&tasks[..batch], false) {
                            Ok(reply) => {
                                assert_eq!(reply.picks.len(), batch);
                                ok += 1;
                            }
                            // once the drain begins: refused or closed
                            Err(_) => break,
                        }
                    }
                    ok
                })
            })
            .collect();
        // let the load build up, then pull the plug mid-stream
        std::thread::sleep(Duration::from_millis(30));
        let served = client(&addr).shutdown().unwrap();
        let ok: u64 = workers.into_iter().map(|w| w.join().unwrap()).sum();
        // every reply a client saw was counted; the daemon may have
        // counted a final answer whose write raced the close
        assert!(served >= ok, "daemon counted {served} < {ok} client-observed replies");
        ok
    });

    let summary = server.join().unwrap();
    assert!(summary.requests >= successes);
    assert!(summary.tasks >= summary.requests, "every request carries ≥1 task");

    // the listener is gone: connecting (or speaking) now fails
    let post = Client::connect(&addr).and_then(|mut c| c.ping());
    assert!(post.is_err(), "daemon accepted a connection after join()");
    std::fs::remove_dir_all(&dir).unwrap();
}
