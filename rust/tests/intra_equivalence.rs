//! Intra-worker parallelism equivalence: with `GPS_INTRA_THREADS > 1`
//! each engine worker fans its gather/scatter sweeps over deterministic
//! CSR chunks, and single-(graph,strategy) partitioning calls fan their
//! per-edge work over the pool — both must be **bit-identical** to the
//! sequential computation. The engine side is pinned across all three
//! transports (final values through `value_hash`, the full `OpCounts`,
//! the simulated-time label and the checksum); the partition side is
//! pinned field-by-field over the whole strategy inventory. This is the
//! property that makes the intra-thread count a pure wall-clock knob:
//! no corpus label, fingerprint or figure can depend on it.

use std::sync::Mutex;

use gps_select::algorithms::{Algorithm, SimOutcome};
use gps_select::engine::cluster::ClusterSpec;
use gps_select::engine::transport::socket;
use gps_select::engine::ExecutionMode;
use gps_select::graph::Graph;
use gps_select::partition::Strategy;
use gps_select::util::pool;
use gps_select::util::rng::Rng;

/// The intra-thread override is process-global; the tests that mutate
/// it serialize on this lock so libtest's parallel runner cannot
/// interleave their settings.
static INTRA_LOCK: Mutex<()> = Mutex::new(());

/// The socket backend spawns worker processes; point it at the repro
/// CLI, which installs the `--worker-rank` hook (the test binary's
/// libtest main does not).
fn use_repro_workers() {
    socket::set_worker_binary(env!("CARGO_BIN_EXE_repro"));
}

fn assert_matches_reference(ctx: &str, sim: &SimOutcome, other: &SimOutcome) {
    assert_eq!(sim.value_hash, other.value_hash, "{ctx}: values must be bit-identical");
    assert_eq!(sim.ops, other.ops, "{ctx}: op counts must match");
    assert_eq!(
        sim.sim.total.to_bits(),
        other.sim.total.to_bits(),
        "{ctx}: simulated time must be bit-identical ({} vs {})",
        sim.sim.total,
        other.sim.total
    );
    assert_eq!(sim.checksum.to_bits(), other.checksum.to_bits(), "{ctx}: checksums must match");
}

fn assert_intra_equivalent(g: &Graph, workers: usize, modes: &[ExecutionMode]) {
    let cfg = ClusterSpec::with_workers(workers);
    let p = Strategy::Hdrf(50).partition(g, workers);
    for a in Algorithm::all() {
        pool::set_intra_threads(1);
        let reference = a.execute(g, &p, &cfg, ExecutionMode::Simulated);
        for intra in [1usize, 2, 4] {
            pool::set_intra_threads(intra);
            for &mode in modes {
                let got = a.execute(g, &p, &cfg, mode);
                let ctx = format!(
                    "{}/{} at {workers} workers, intra={intra} ({} mode)",
                    g.name,
                    a.name(),
                    mode.name()
                );
                assert_matches_reference(&ctx, &reference, &got);
            }
        }
    }
    pool::set_intra_threads(0);
}

/// Fast debug-mode pin: every algorithm on the sequential oracle, small
/// directed and undirected graphs (the undirected case exercises the
/// both-direction chunked sweeps), intra ∈ {1, 2, 4}.
#[test]
fn intra_chunked_sweeps_match_sequential_simulated() {
    let _guard = INTRA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Rng::new(7171);
    let gd =
        gps_select::graph::gen::chung_lu::generate("intra-eq-d", 400, 2400, 2.2, true, &mut rng);
    assert_intra_equivalent(&gd, 4, &[ExecutionMode::Simulated]);
    let gu = gps_select::graph::gen::erdos::generate("intra-eq-u", 300, 1500, false, &mut rng);
    assert_intra_equivalent(&gu, 3, &[ExecutionMode::Simulated]);
}

/// The full acceptance matrix (release-only; the debug tier skips on
/// the `bit_identical_to_simulated` name filter): all 8 algorithms ×
/// intra ∈ {1, 2, 4} × all three transports on a ~40k-edge power-law
/// graph, every cell compared against the intra=1 simulated reference.
#[test]
fn intra_threads_are_bit_identical_to_simulated_all_transports() {
    let _guard = INTRA_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    use_repro_workers();
    let mut rng = Rng::new(9090);
    let g =
        gps_select::graph::gen::chung_lu::generate("intra-eq", 8_000, 40_000, 2.1, true, &mut rng);
    assert_intra_equivalent(
        &g,
        4,
        &[ExecutionMode::Simulated, ExecutionMode::Threaded, ExecutionMode::Socket],
    );
}

/// Chunked single-partition parallelism ≡ sequential, field by field,
/// for every strategy in the inventory plus Oblivious — on a graph past
/// the parallel-path threshold so the chunked code actually runs.
#[test]
fn parallel_single_partition_matches_sequential_for_all_strategies() {
    let mut rng = Rng::new(6161);
    let g = gps_select::graph::gen::erdos::generate("part-eq", 6_000, 40_000, true, &mut rng);
    let workers = 8;
    for s in Strategy::all() {
        let seq = s.partition_with_threads(&g, workers, 1);
        for threads in [2usize, 4, 8] {
            let par = s.partition_with_threads(&g, workers, threads);
            let ctx = format!("{} at {threads} threads", s.name());
            assert_eq!(seq.edge_worker, par.edge_worker, "{ctx}: edge assignment");
            assert_eq!(seq.edges_per_worker, par.edges_per_worker, "{ctx}: per-worker counts");
            assert_eq!(seq.replicas, par.replicas, "{ctx}: replica sets");
            assert_eq!(seq.master, par.master, "{ctx}: master designation");
        }
    }
}
