//! The `GPS_THREADS` determinism contract: the parallel corpus builder
//! and the full pipeline must produce bit-identical execution logs and
//! identical strategy selections for the same seed, regardless of the
//! thread count — on either engine execution mode.

use gps_select::dataset::logs::LogStore;
use gps_select::engine::cluster::ClusterSpec;
use gps_select::engine::ExecutionMode;
use gps_select::eval::pipeline::{run, PipelineConfig};
use gps_select::ml::gbdt::GbdtParams;

/// Bit-exact log equality: task identity, feature vectors and the f64
/// time labels compared by bit pattern, plus the per-graph data
/// features.
fn assert_stores_identical(a: &LogStore, b: &LogStore) {
    assert_eq!(a.logs.len(), b.logs.len());
    for (x, y) in a.logs.iter().zip(&b.logs) {
        assert_eq!(x.graph, y.graph);
        assert_eq!(x.algorithm, y.algorithm);
        assert_eq!(x.strategy, y.strategy);
        assert_eq!(
            x.time.to_bits(),
            y.time.to_bits(),
            "time bits differ for {}/{}/{}",
            x.graph,
            x.algorithm,
            x.strategy.name()
        );
        assert_eq!(x.features.algo, y.features.algo, "{}/{}", x.graph, x.algorithm);
        assert_eq!(x.features.data, y.features.data, "{}", x.graph);
    }
    assert_eq!(a.graph_features, b.graph_features);
}

#[test]
fn corpus_is_bit_identical_across_thread_counts() {
    let cfg = ClusterSpec::with_workers(16);
    let serial =
        LogStore::build_corpus_parallel(0.002, 7, &cfg, 1, ExecutionMode::Simulated).unwrap();
    assert_eq!(serial.logs.len(), 12 * 8 * 11);
    for threads in [2usize, 4, 7] {
        let parallel =
            LogStore::build_corpus_parallel(0.002, 7, &cfg, threads, ExecutionMode::Simulated)
                .unwrap();
        assert_stores_identical(&serial, &parallel);
    }
}

/// The same contract with the corpus running on the thread-per-worker
/// engine: bit-identical across pool thread counts, and — because the
/// two engine backends are bit-identical — equal to the simulated-mode
/// corpus as well.
#[test]
fn corpus_threaded_mode_matches_simulated_across_thread_counts() {
    let cfg = ClusterSpec::with_workers(4);
    let reference =
        LogStore::build_corpus_parallel(0.002, 7, &cfg, 1, ExecutionMode::Simulated).unwrap();
    for threads in [1usize, 3] {
        let threaded =
            LogStore::build_corpus_parallel(0.002, 7, &cfg, threads, ExecutionMode::Threaded)
                .unwrap();
        assert_stores_identical(&reference, &threaded);
    }
}

#[test]
fn pipeline_selections_identical_across_thread_counts() {
    let config = |threads: usize| PipelineConfig {
        threads,
        scale: 0.002,
        augment_cap: Some(2_000),
        r_hi: 3,
        gbdt: GbdtParams { n_estimators: 40, max_depth: 5, ..GbdtParams::fast() },
        ..PipelineConfig::fast_test()
    };
    let one = run(config(1)).unwrap();
    let four = run(config(4)).unwrap();
    assert_stores_identical(&one.store, &four.store);
    assert_eq!(one.synthetic_count, four.synthetic_count);
    assert_eq!(one.tasks.len(), four.tasks.len());
    for (x, y) in one.tasks.iter().zip(&four.tasks) {
        assert_eq!(x.graph, y.graph);
        assert_eq!(x.algorithm, y.algorithm);
        assert_eq!(
            x.selected,
            y.selected,
            "selection differs for {}/{}",
            x.graph,
            x.algorithm.name()
        );
        assert_eq!(x.rank, y.rank, "{}/{}", x.graph, x.algorithm.name());
        assert_eq!(x.t_sel.to_bits(), y.t_sel.to_bits(), "{}/{}", x.graph, x.algorithm.name());
    }
}
