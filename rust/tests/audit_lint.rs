//! End-to-end tests of `audit::` (the static determinism linter) and
//! the `repro audit` CLI gate, driven by the small fixture trees under
//! `tests/audit_fixtures/`. Fixture files live in subdirectories, so
//! cargo never compiles them — each tree exists purely to be scanned.
//!
//! Per rule the fixtures cover the full gate matrix: the bad tree
//! trips, the good tree passes, a justified `audit:allow` suppresses,
//! and a bare allow both fails itself and suppresses nothing.

use std::path::{Path, PathBuf};
use std::process::Command;

use gps_select::audit::{
    audit_tree, audit_tree_with_budget, Report, DEFAULT_UNWRAP_BUDGET, RULE_ALLOW,
    RULE_FLOAT_FMT, RULE_HASH, RULE_INSTANT, RULE_PARTIAL_CMP, RULE_UNWRAP_BUDGET,
};

fn fixture(tree: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/audit_fixtures").join(tree)
}

fn audit(tree: &str) -> Report {
    audit_tree(&fixture(tree)).unwrap_or_else(|e| panic!("audit of {tree}: {e}"))
}

fn rules(r: &Report) -> Vec<&'static str> {
    r.violations.iter().map(|v| v.rule).collect()
}

/// bad trips / good passes / justified allow suppresses / bare allow
/// fails, for each per-site rule.
#[test]
fn hash_rule_fixture_matrix() {
    let bad = audit("hash/bad");
    assert_eq!(rules(&bad), vec![RULE_HASH, RULE_HASH, RULE_HASH], "{:?}", bad.violations);
    assert!(audit("hash/good").is_clean());
    assert!(audit("hash/allow").is_clean());
    let bare = audit("hash/allow_bare");
    assert_eq!(rules(&bare), vec![RULE_ALLOW, RULE_HASH], "{:?}", bare.violations);
}

#[test]
fn partial_cmp_rule_fixture_matrix() {
    let bad = audit("partial_cmp/bad");
    assert_eq!(rules(&bad), vec![RULE_PARTIAL_CMP], "{:?}", bad.violations);
    assert_eq!(bad.violations[0].file, "ml/sort.rs");
    assert_eq!(bad.violations[0].line, 4);
    assert!(audit("partial_cmp/good").is_clean());
    assert!(audit("partial_cmp/allow").is_clean());
    assert_eq!(rules(&audit("partial_cmp/allow_bare")), vec![RULE_ALLOW, RULE_PARTIAL_CMP]);
}

#[test]
fn float_fmt_rule_fixture_matrix() {
    let bad = audit("float_fmt/bad");
    assert_eq!(rules(&bad), vec![RULE_FLOAT_FMT], "{:?}", bad.violations);
    assert!(bad.violations[0].message.contains("scale"), "{:?}", bad.violations);
    // the sanctioned f64_hex(..) call in the good tree is not flagged
    assert!(audit("float_fmt/good").is_clean());
    assert!(audit("float_fmt/allow").is_clean());
    assert_eq!(rules(&audit("float_fmt/allow_bare")), vec![RULE_ALLOW, RULE_FLOAT_FMT]);
}

#[test]
fn instant_rule_fixture_matrix() {
    let bad = audit("instant/bad");
    assert_eq!(rules(&bad), vec![RULE_INSTANT], "{:?}", bad.violations);
    // the good tree holds the identical read in engine/mod.rs — the
    // blessed measured-label choke point
    assert!(audit("instant/good").is_clean());
    assert!(audit("instant/allow").is_clean());
    assert_eq!(rules(&audit("instant/allow_bare")), vec![RULE_ALLOW, RULE_INSTANT]);
}

#[test]
fn unwrap_budget_counts_scope_and_tests_correctly() {
    // 2 sites in engine/a.rs + 1 in dataset/b.rs; the etrm/c.rs unwrap
    // and dataset/b.rs's #[cfg(test)] unwrap are out of scope
    let within = audit_tree_with_budget(&fixture("budget"), 3).unwrap();
    assert!(within.is_clean(), "{:?}", within.violations);
    assert_eq!(within.unwrap_sites, 3);
    let over = audit_tree_with_budget(&fixture("budget"), 1).unwrap();
    assert_eq!(rules(&over), vec![RULE_UNWRAP_BUDGET, RULE_UNWRAP_BUDGET]);
    assert!(over.violations[0].message.contains("budget of 1"), "{:?}", over.violations);
}

#[test]
fn test_regions_are_exempt() {
    let r = audit("test_only");
    assert!(r.is_clean(), "{:?}", r.violations);
    assert_eq!(r.unwrap_sites, 0);
}

/// The gate itself: the crate's own tree must audit clean under the
/// default budget (this is what CI runs via `repro audit`).
#[test]
fn crate_sources_audit_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let r = audit_tree(&src).unwrap();
    assert!(
        r.is_clean(),
        "rust/src must audit clean:\n{}",
        r.render_text()
    );
    assert!(
        r.unwrap_sites <= DEFAULT_UNWRAP_BUDGET,
        "unwrap ratchet exceeded: {} sites > budget {}",
        r.unwrap_sites,
        DEFAULT_UNWRAP_BUDGET
    );
    assert!(r.files_scanned > 50, "expected the full tree, saw {}", r.files_scanned);
}

#[test]
fn cli_exits_nonzero_on_violations_and_writes_json() {
    let json = std::env::temp_dir()
        .join(format!("gps_audit_cli_bad_{}.json", std::process::id()));
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "audit",
            "--root",
            fixture("instant/bad").to_str().unwrap(),
            "--json",
            json.to_str().unwrap(),
        ])
        .output()
        .expect("spawn repro audit");
    assert!(!out.status.success(), "audit of a bad tree must fail");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("[instant-now]"), "{stdout}");
    assert!(stdout.contains("fix:"), "{stdout}");
    // the JSON report is written before the exit code is decided, so CI
    // can upload it from a failing run
    let doc = std::fs::read_to_string(&json).expect("json report exists");
    assert!(doc.contains("\"clean\": false"), "{doc}");
    assert!(doc.contains("\"rule\": \"instant-now\""), "{doc}");
    std::fs::remove_file(&json).ok();
}

#[test]
fn cli_passes_on_clean_tree() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let json = std::env::temp_dir()
        .join(format!("gps_audit_cli_ok_{}.json", std::process::id()));
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["audit", "--root", src.to_str().unwrap(), "--json", json.to_str().unwrap()])
        .output()
        .expect("spawn repro audit");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("violation(s)"), "{stdout}");
    let doc = std::fs::read_to_string(&json).expect("json report exists");
    assert!(doc.contains("\"clean\": true"), "{doc}");
    std::fs::remove_file(&json).ok();
}

#[test]
fn cli_honours_explicit_unwrap_budget() {
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "audit",
            "--root",
            fixture("budget").to_str().unwrap(),
            "--unwrap-budget",
            "1",
        ])
        .output()
        .expect("spawn repro audit");
    assert!(!out.status.success(), "3 sites against a budget of 1 must fail");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("[unwrap-budget]"), "{stdout}");
    assert!(stdout.contains("unwrap budget 3/1 used"), "{stdout}");
}
