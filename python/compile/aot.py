"""AOT lowering: JAX/Pallas models → HLO *text* artifacts for the Rust
PJRT runtime.

Interchange format is HLO text, NOT serialized HloModuleProto — jax ≥0.5
emits protos with 64-bit instruction ids which the published ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out ../artifacts

Writes one ``<name>.hlo.txt`` per entry point plus ``manifest.txt``
describing the static shapes the Rust side must pad to.
"""

import argparse
import functools
import os

import jax

jax.config.update("jax_enable_x64", True)  # moments run in f64

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# ---- static artifact shapes (mirrored in rust/src/runtime/mod.rs) ----
MOMENTS_N = 1 << 16          # degree-array chunk (rust merges chunks)
GBDT_BATCH = 16              # ≥ the 11-strategy inventory
GBDT_FEATURES = 59           # features::encoding::FEATURE_DIM (52 paper cols + 7 cluster)
GBDT_TREES = 1024            # ≥ the paper's n_estimators = 1000
GBDT_NODES = 256             # padded nodes per tree
GBDT_DEPTH = 15              # paper max_depth
MLP_BATCH = 64
MLP_HIDDEN = 64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all():
    """Lower every artifact; returns {name: hlo_text}."""
    f32, f64, i32 = jnp.float32, jnp.float64, jnp.int32
    flat = GBDT_TREES * GBDT_NODES
    arts = {}

    arts["moments"] = to_hlo_text(
        jax.jit(model.degree_moments).lower(spec((MOMENTS_N,), f64))
    )

    etrm = functools.partial(
        model.etrm_predict,
        n_trees=GBDT_TREES, max_nodes=GBDT_NODES, depth=GBDT_DEPTH,
    )
    arts["gbdt_predict"] = to_hlo_text(
        jax.jit(etrm).lower(
            spec((GBDT_BATCH, GBDT_FEATURES), f32),
            spec((flat,), i32),   # feature
            spec((flat,), f32),   # threshold
            spec((flat,), i32),   # left
            spec((flat,), i32),   # right
            spec((flat,), f32),   # value
            spec((2,), f32),      # [base_score, learning_rate]
        )
    )

    arts["mlp_predict"] = to_hlo_text(
        jax.jit(model.mlp_predict).lower(
            spec((MLP_BATCH, GBDT_FEATURES), f32),
            spec((GBDT_FEATURES, MLP_HIDDEN), f32),
            spec((MLP_HIDDEN,), f32),
            spec((MLP_HIDDEN,), f32),
            spec((), f32),
        )
    )

    arts["mlp_train_step"] = to_hlo_text(
        jax.jit(model.mlp_train_step).lower(
            spec((GBDT_FEATURES, MLP_HIDDEN), f32),
            spec((MLP_HIDDEN,), f32),
            spec((MLP_HIDDEN,), f32),
            spec((), f32),
            spec((MLP_BATCH, GBDT_FEATURES), f32),
            spec((MLP_BATCH,), f32),
            spec((), f32),
        )
    )
    return arts


def manifest() -> str:
    return (
        f"moments_n {MOMENTS_N}\n"
        f"gbdt_batch {GBDT_BATCH}\n"
        f"gbdt_features {GBDT_FEATURES}\n"
        f"gbdt_trees {GBDT_TREES}\n"
        f"gbdt_nodes {GBDT_NODES}\n"
        f"gbdt_depth {GBDT_DEPTH}\n"
        f"mlp_batch {MLP_BATCH}\n"
        f"mlp_hidden {MLP_HIDDEN}\n"
    )


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for name, text in lower_all().items():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write(manifest())
    print(f"wrote {os.path.join(args.out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
