"""L2: the JAX compute graphs that get AOT-lowered to PJRT artifacts.

Four entry points, each a pure function returning a tuple (lowered with
``return_tuple=True`` so the Rust side unwraps with ``to_tupleN``):

* :func:`degree_moments` — data-feature power sums (calls the L1
  ``moments`` kernel).
* :func:`etrm_predict` — GBDT forest inference over encoded tasks
  (calls the L1 ``gbdt`` kernel); the tree tensors are runtime inputs.
* :func:`mlp_predict` — the MLP baseline forward pass (L1 fused
  dense+ReLU kernel for the hot layer).
* :func:`mlp_train_step` — one SGD step of the MLP baseline with
  fwd/bwd via ``jax.grad`` (the L2 "model fwd/bwd" path); returns the
  updated parameters and the batch loss.

Python never runs at request time: ``aot.py`` lowers these once to HLO
text and the Rust runtime executes the compiled artifacts.
"""

import jax
import jax.numpy as jnp

from compile.kernels import gbdt as gbdt_kernel
from compile.kernels import mlp as mlp_kernel
from compile.kernels import moments as moments_kernel


def degree_moments(x):
    """Power sums of a zero-padded degree array (f64)."""
    return (moments_kernel.power_sums(x),)


def etrm_predict(x, feat, thr, left, right, val, scal, *, n_trees,
                 max_nodes, depth):
    """Transformed-space execution-time predictions for a feature batch."""
    out = gbdt_kernel.forest_predict(
        x, feat, thr, left, right, val, scal,
        n_trees=n_trees, max_nodes=max_nodes, depth=depth,
    )
    return (out,)


def mlp_predict(x, w1, b1, w2, b2):
    """MLP baseline forward pass (already-normalised inputs)."""
    h = mlp_kernel.dense_relu(x, w1, b1)
    return (h @ w2 + b2,)


def _mlp_loss(params, x, y):
    w1, b1, w2, b2 = params
    # pure-jnp forward for differentiability (interpret-mode pallas
    # calls are not AD-transparent); the kernel and this forward are
    # asserted equal in python/tests.
    h = jnp.maximum(x @ w1 + b1[None, :], 0.0)
    pred = h @ w2 + b2
    err = pred - y
    # ½·mean(err²): its gradient is (1/n)·Σ err·∂pred, exactly the
    # update rust's Mlp::train_step applies (lr/n folded the same way)
    return 0.5 * jnp.mean(err * err)


def mlp_train_step(w1, b1, w2, b2, x, y, lr):
    """One SGD step; returns (w1', b1', w2', b2', loss)."""
    params = (w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(_mlp_loss)(params, x, y)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new, 2.0 * loss)  # report mean(err²) like the rust twin
