"""L1 Pallas kernel: fused dense + ReLU (the MLP baseline's hot layer).

``h = max(x @ W1 + b1, 0)`` in one kernel — the matmul feeds the TPU
MXU (f32 here; bf16 on real hardware) and the bias/ReLU epilogue runs
in-register before the tile is written back, the standard fusion that
saves one HBM round-trip per activation tile.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_relu_kernel(x_ref, w_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(
        jnp.dot(x_ref[...], w_ref[...]) + b_ref[...][None, :], 0.0
    )


@jax.jit
def dense_relu(x, w, b):
    """Fused first layer: x [B,F] @ w [F,H] + b [H], ReLU."""
    batch = x.shape[0]
    hidden = w.shape[1]
    return pl.pallas_call(
        _dense_relu_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, hidden), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, b)
