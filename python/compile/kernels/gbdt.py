"""L1 Pallas kernel: GBDT forest inference (the ETRM's Fig-2 step 3).

Evaluates a *fixed-capacity* forest over a batch of encoded task
features. Tree tensors (feature / threshold / left / right / value,
flattened ``[n_trees · max_nodes]``) are **runtime inputs** of the
compiled artifact, so one AOT compilation serves every trained model up
to the padded capacity — the coordinator re-uploads tensors when the
model is retrained, never recompiles.

Traversal is data-parallel over (batch × trees): ``depth`` unrolled
steps of ``node = x[feat[node]] <= thr[node] ? left : right`` with
self-referencing leaves, i.e. pure gathers — VPU work with no MXU
involvement; the natural TPU blocking is over the batch with tree
tensors resident in VMEM (see DESIGN.md §Perf for the footprint).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _forest_kernel(n_trees, max_nodes, depth, x_ref, feat_ref, thr_ref,
                   left_ref, right_ref, val_ref, scal_ref, o_ref):
    x = x_ref[...]                      # [B, F]
    feat = feat_ref[...]                # [T·N] i32
    thr = thr_ref[...]                  # [T·N] f32
    left = left_ref[...]                # [T·N] i32
    right = right_ref[...]              # [T·N] i32
    val = val_ref[...]                  # [T·N] f32
    batch = x.shape[0]
    tree_off = (jnp.arange(n_trees, dtype=jnp.int32) * max_nodes)[None, :]
    node = jnp.zeros((batch, n_trees), dtype=jnp.int32)
    for _ in range(depth):              # static unroll: fixed iterations
        idx = tree_off + node
        f = jnp.take(feat, idx)         # [B, T]
        t = jnp.take(thr, idx)
        l = jnp.take(left, idx)
        r = jnp.take(right, idx)
        xv = jnp.take_along_axis(x, jnp.maximum(f, 0), axis=1)
        node = jnp.where((f >= 0) & (xv <= t), l, r)
    leaf = jnp.take(val, tree_off + node)
    base, lr = scal_ref[0], scal_ref[1]
    o_ref[...] = base + lr * jnp.sum(leaf, axis=1)


@functools.partial(jax.jit, static_argnames=("n_trees", "max_nodes", "depth"))
def forest_predict(x, feat, thr, left, right, val, scal, *, n_trees,
                   max_nodes, depth):
    """Transformed-space ensemble prediction for a batch.

    ``scal = [base_score, learning_rate]``; the inverse target transform
    (`expm1` for log targets) is applied by the caller.
    """
    batch, _ = x.shape
    kern = functools.partial(_forest_kernel, n_trees, max_nodes, depth)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, feat, thr, left, right, val, scal)
