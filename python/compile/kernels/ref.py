"""Pure-jnp oracles for every L1 kernel — the correctness ground truth.

pytest (`python/tests/`) sweeps shapes and random inputs with
hypothesis and asserts `assert_allclose(kernel, ref)`.
"""

import jax.numpy as jnp


def power_sums_ref(x):
    """[Σx, Σx², Σx³, Σx⁴] of a 1-D array."""
    x = x.astype(jnp.float64)
    x2 = x * x
    return jnp.stack([jnp.sum(x), jnp.sum(x2), jnp.sum(x2 * x), jnp.sum(x2 * x2)])


def forest_predict_ref(x, feat, thr, left, right, val, scal, *, n_trees,
                       max_nodes, depth):
    """Forest traversal oracle (mirrors `GbdtTensors::predict_transformed`)."""
    batch = x.shape[0]
    out = jnp.full((batch,), scal[0], dtype=jnp.float32)
    tree_off = (jnp.arange(n_trees, dtype=jnp.int32) * max_nodes)[None, :]
    node = jnp.zeros((batch, n_trees), dtype=jnp.int32)
    for _ in range(depth):
        idx = tree_off + node
        f = jnp.take(feat, idx)
        t = jnp.take(thr, idx)
        l = jnp.take(left, idx)
        r = jnp.take(right, idx)
        xv = jnp.take_along_axis(x, jnp.maximum(f, 0), axis=1)
        node = jnp.where((f >= 0) & (xv <= t), l, r)
    leaf = jnp.take(val, tree_off + node)
    return out + scal[1] * jnp.sum(leaf, axis=1)


def dense_relu_ref(x, w, b):
    """max(x @ w + b, 0)."""
    return jnp.maximum(x @ w + b[None, :], 0.0)


def mlp_predict_ref(x, w1, b1, w2, b2):
    """Two-layer MLP forward."""
    h = dense_relu_ref(x, w1, b1)
    return h @ w2 + b2
