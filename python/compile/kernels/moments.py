"""L1 Pallas kernel: degree-distribution power sums (Table 3 features).

Computes ``[Σx, Σx², Σx³, Σx⁴]`` over a zero-padded degree array with a
1-D grid of blocks, accumulating per-block partial sums into a single
revisited output block — the classic reduction schedule (on TPU the
output tile stays resident in VMEM across grid steps; zero padding is
exact for power sums, so no mask is needed).

float64 throughout: degree⁴ on a web graph reaches ~1e20, far beyond
f32's 24-bit mantissa.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block length for the 1-D reduction grid. 4096 f64 elements = 32 KiB of
# VMEM per input tile — small against the ~16 MiB budget, large enough
# to amortise grid overhead.
BLOCK = 4096


def _power_sums_kernel(x_ref, o_ref):
    """One grid step: fold a block's four power sums into the output."""
    i = pl.program_id(0)
    x = x_ref[...]
    x2 = x * x
    partial = jnp.stack(
        [jnp.sum(x), jnp.sum(x2), jnp.sum(x2 * x), jnp.sum(x2 * x2)]
    )

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=())
def power_sums(x):
    """Power sums of a 1-D f64 array whose length is a BLOCK multiple."""
    (n,) = x.shape
    assert n % BLOCK == 0, f"input length {n} must be a multiple of {BLOCK}"
    return pl.pallas_call(
        _power_sums_kernel,
        grid=(n // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((4,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((4,), jnp.float64),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x)
