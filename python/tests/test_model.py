"""L2 model graphs: shapes, semantics and the AOT lowering path."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_mlp_predict_matches_pure_forward():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((8, 10)).astype(np.float32)
    w1 = rng.standard_normal((10, 16)).astype(np.float32)
    b1 = rng.standard_normal(16).astype(np.float32)
    w2 = rng.standard_normal(16).astype(np.float32)
    b2 = np.float32(0.3)
    (got,) = model.mlp_predict(
        jnp.asarray(x), jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2), b2
    )
    want = ref.mlp_predict_ref(
        jnp.asarray(x), jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2), b2
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_mlp_train_step_reduces_loss():
    rng = np.random.default_rng(4)
    f, h, b = 6, 12, 32
    w1 = jnp.asarray(rng.standard_normal((f, h)).astype(np.float32) * 0.3)
    b1 = jnp.zeros(h, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal(h).astype(np.float32) * 0.3)
    b2 = jnp.float32(0.0)
    x = jnp.asarray(rng.standard_normal((b, f)).astype(np.float32))
    y = jnp.asarray((np.asarray(x)[:, 0] * 2.0).astype(np.float32))
    lr = jnp.float32(0.05)
    losses = []
    for _ in range(60):
        w1, b1, w2, b2, loss = model.mlp_train_step(w1, b1, w2, b2, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.2, losses[::10]


def test_mlp_train_step_gradient_matches_finite_difference():
    # pin jax.grad against a finite difference on one weight
    rng = np.random.default_rng(5)
    f, h, b = 3, 4, 8
    w1 = rng.standard_normal((f, h)).astype(np.float32) * 0.5
    b1 = np.zeros(h, np.float32)
    w2 = rng.standard_normal(h).astype(np.float32) * 0.5
    b2 = np.float32(0.1)
    x = rng.standard_normal((b, f)).astype(np.float32)
    y = rng.standard_normal(b).astype(np.float32)

    def loss_of(w1v):
        hmat = np.maximum(x @ w1v + b1[None, :], 0.0)
        pred = hmat @ w2 + b2
        return float(np.mean((pred - y) ** 2))

    lr = 1.0
    out = model.mlp_train_step(
        jnp.asarray(w1), jnp.asarray(b1), jnp.asarray(w2), jnp.float32(b2),
        jnp.asarray(x), jnp.asarray(y), jnp.float32(lr),
    )
    grad_w1 = (w1 - np.asarray(out[0]))  # lr = 1 → gradient itself
    eps = 1e-3
    w1p = w1.copy()
    w1p[0, 0] += eps
    w1m = w1.copy()
    w1m[0, 0] -= eps
    # the train step descends ½·mean(err²), so its gradient is half the
    # finite difference of mean(err²)
    fd = 0.5 * (loss_of(w1p) - loss_of(w1m)) / (2 * eps)
    assert abs(grad_w1[0, 0] - fd) < 5e-3, (grad_w1[0, 0], fd)


def test_lowering_produces_hlo_text():
    arts = aot.lower_all()
    assert set(arts) == {"moments", "gbdt_predict", "mlp_predict", "mlp_train_step"}
    for name, text in arts.items():
        assert text.startswith("HloModule"), f"{name} lowered to {text[:40]!r}"
        assert "ENTRY" in text, name


def test_manifest_matches_constants():
    m = aot.manifest()
    assert f"gbdt_features {aot.GBDT_FEATURES}" in m
    assert f"gbdt_trees {aot.GBDT_TREES}" in m
    assert aot.GBDT_TREES >= 1000, "capacity must cover the paper's n_estimators"
