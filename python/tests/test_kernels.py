"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

hypothesis sweeps shapes and values; every kernel must match its
`ref.py` oracle to float tolerance.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gbdt, mlp, moments, ref

# ---------------------------------------------------------------- moments


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1.0, 100.0, 10_000.0]),
)
def test_power_sums_matches_ref(blocks, seed, scale):
    rng = np.random.default_rng(seed)
    n = blocks * moments.BLOCK
    x = jnp.asarray(rng.random(n) * scale, dtype=jnp.float64)
    got = moments.power_sums(x)
    want = ref.power_sums_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-9)


def test_power_sums_zero_padding_exact():
    rng = np.random.default_rng(7)
    x = rng.random(100) * 50.0
    padded = np.zeros(moments.BLOCK, dtype=np.float64)
    padded[:100] = x
    got = moments.power_sums(jnp.asarray(padded))
    want = ref.power_sums_ref(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-9)


def test_power_sums_rejects_unaligned():
    with pytest.raises(AssertionError):
        moments.power_sums(jnp.zeros(moments.BLOCK + 1, dtype=jnp.float64))


# ---------------------------------------------------------------- gbdt


def random_forest(rng, n_trees, max_nodes, features):
    """Random *valid* forest tensors: node i can only point to children
    with larger indices (or itself = leaf), so traversal terminates."""
    feat = rng.integers(-1, features, size=(n_trees * max_nodes,)).astype(np.int32)
    thr = rng.standard_normal(n_trees * max_nodes).astype(np.float32)
    left = np.zeros(n_trees * max_nodes, dtype=np.int32)
    right = np.zeros(n_trees * max_nodes, dtype=np.int32)
    val = rng.standard_normal(n_trees * max_nodes).astype(np.float32) * 0.1
    for t in range(n_trees):
        for i in range(max_nodes):
            idx = t * max_nodes + i
            if feat[idx] >= 0 and i + 2 < max_nodes:
                left[idx] = rng.integers(i + 1, max_nodes)
                right[idx] = rng.integers(i + 1, max_nodes)
            else:
                feat[idx] = -1
                left[idx] = i
                right[idx] = i
    return feat, thr, left, right, val


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    batch=st.sampled_from([1, 4, 16]),
    n_trees=st.sampled_from([1, 8, 32]),
    depth=st.sampled_from([1, 4, 8]),
)
def test_forest_matches_ref(seed, batch, n_trees, depth):
    rng = np.random.default_rng(seed)
    max_nodes = 16
    features = 6
    feat, thr, left, right, val = random_forest(rng, n_trees, max_nodes, features)
    x = rng.standard_normal((batch, features)).astype(np.float32)
    scal = np.array([0.5, 0.1], dtype=np.float32)
    kw = dict(n_trees=n_trees, max_nodes=max_nodes, depth=depth)
    got = gbdt.forest_predict(
        jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr),
        jnp.asarray(left), jnp.asarray(right), jnp.asarray(val),
        jnp.asarray(scal), **kw,
    )
    want = ref.forest_predict_ref(
        jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr),
        jnp.asarray(left), jnp.asarray(right), jnp.asarray(val),
        jnp.asarray(scal), **kw,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_forest_single_stump_by_hand():
    # stump: x0 <= 0 → leaf1 (-1), else leaf2 (+1); base 10, lr 1
    feat = np.array([0, -1, -1], dtype=np.int32)
    thr = np.array([0.0, 0.0, 0.0], dtype=np.float32)
    left = np.array([1, 1, 2], dtype=np.int32)
    right = np.array([2, 1, 2], dtype=np.int32)
    val = np.array([0.0, -1.0, 1.0], dtype=np.float32)
    scal = np.array([10.0, 1.0], dtype=np.float32)
    x = np.array([[-5.0], [5.0]], dtype=np.float32)
    out = gbdt.forest_predict(
        jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr),
        jnp.asarray(left), jnp.asarray(right), jnp.asarray(val),
        jnp.asarray(scal), n_trees=1, max_nodes=3, depth=4,
    )
    np.testing.assert_allclose(np.asarray(out), [9.0, 11.0])


# ---------------------------------------------------------------- mlp


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    batch=st.sampled_from([1, 8, 64]),
    feats=st.sampled_from([3, 52]),
    hidden=st.sampled_from([8, 64]),
)
def test_dense_relu_matches_ref(seed, batch, feats, hidden):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch, feats)).astype(np.float32)
    w = rng.standard_normal((feats, hidden)).astype(np.float32)
    b = rng.standard_normal(hidden).astype(np.float32)
    got = mlp.dense_relu(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    want = ref.dense_relu_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert (np.asarray(got) >= 0).all()
